"""Observability subsystem (repro.obs): metrics, cost tables, bubbles.

Pins the PR-level acceptance invariants:

* histogram bucket-edge semantics and the deferred (lazy-fold) observe path;
* EWMA convergence on drifting costs + OnlineCostTable <-> CostModel round
  trips;
* bubble decomposition accounts for 100% of per-stage idle time (categories
  sum exactly to makespan - busy) on chain, DAG and precommitted runs;
* attaching a MetricsRegistry never changes a scheduling decision, and a
  metrics-annotated recorded trace still replays exactly.
"""
import json
import math

import pytest

from repro.core import (
    CostModel,
    HintKind,
    JitterModel,
    Kind,
    PipelineSpec,
    StageGraph,
    Task,
)
from repro.obs import (
    CATEGORIES,
    DEPTH_EDGES,
    DURATION_EDGES,
    Ewma,
    Histogram,
    MetricsRegistry,
    OnlineCostTable,
    compare,
    decompose,
    log_edges,
)
from repro.runtime.rrfp import ActorConfig, ActorDriver, Trace
from repro.runtime.rrfp import trace as _tr


def det_costs(S, f=1.0, b=2.0, w=0.0, comm=1e-3, **kw):
    return CostModel.uniform(
        S, f=f, b=b, w=w, comm_base=comm,
        compute_jitter=JitterModel(), comm_jitter=JitterModel(), **kw,
    )


def run_recorded(spec, cm, **cfg_kw):
    cfg = ActorConfig(record_trace=True, **cfg_kw)
    driver = ActorDriver(spec, cm, cfg)
    res = driver.run()
    return res, driver.trace


def dag_spec(num_mb=4):
    g = StageGraph(5, ((0, 2), (1, 2), (2, 3), (3, 4)))
    return PipelineSpec(5, num_mb, graph=g)


# ---------------------------------------------------------------------------
# Histogram
# ---------------------------------------------------------------------------
class TestHistogram:
    def test_bucket_edge_semantics(self):
        # bucket i counts edges[i-1] < x <= edges[i]; 0 = underflow (x <=
        # edges[0]); the last bucket is overflow (x > edges[-1])
        h = Histogram(edges=(1.0, 2.0, 4.0))
        for x in (0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 5.0):
            h.observe(x)
        assert h.counts == [2, 2, 2, 1]
        assert h.count == 7
        assert h.total == pytest.approx(sum((0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 5.0)))

    def test_deferred_fold_is_transparent(self):
        # observe is an append; the fold runs at the first read and further
        # observations after a read fold correctly on the next read
        h = Histogram(edges=(1.0, 10.0))
        h.observe(0.5)
        assert h._pending  # queued, not yet bucketed
        assert h.count == 1  # property read folds
        assert not h._pending
        h.observe(5.0)
        h.observe(50.0)
        assert h.counts == [1, 1, 1]
        assert h.total == pytest.approx(55.5)

    def test_edges_must_strictly_increase(self):
        with pytest.raises(ValueError):
            Histogram(edges=(1.0, 1.0, 2.0))
        with pytest.raises(ValueError):
            Histogram(edges=(2.0, 1.0))

    def test_default_edge_sets(self):
        assert Histogram().edges is DURATION_EDGES
        assert Histogram(DEPTH_EDGES).edges is DEPTH_EDGES
        # log-spaced: constant ratio between consecutive edges
        e = log_edges(1e-6, 1e2, 8)
        ratios = [e[i + 1] / e[i] for i in range(len(e) - 1)]
        assert all(r == pytest.approx(ratios[0], rel=1e-9) for r in ratios)
        with pytest.raises(ValueError):
            log_edges(0.0, 1.0, 4)

    def test_merge_requires_same_edges(self):
        a, b = Histogram(edges=(1.0, 2.0)), Histogram(edges=(1.0, 2.0))
        a.observe(0.5)
        b.observe(1.5)
        b.observe(9.0)
        a.merge(b)
        assert a.counts == [1, 1, 1]
        assert a.count == 3
        assert a.total == pytest.approx(11.0)
        with pytest.raises(ValueError):
            a.merge(Histogram(edges=(1.0, 3.0)))

    def test_quantile_is_bucketed_upper_bound(self):
        h = Histogram(edges=(1.0, 2.0, 4.0))
        assert h.quantile(0.5) == 0.0  # empty
        for x in (0.5, 1.5, 1.5, 3.0):
            h.observe(x)
        assert h.quantile(0.25) == 1.0
        assert h.quantile(0.75) == 2.0
        assert h.quantile(1.0) == 4.0
        h.observe(100.0)  # overflow bucket reports inf
        assert h.quantile(1.0) == math.inf

    def test_mean_exact_despite_bucketing(self):
        h = Histogram(edges=(1.0, 100.0))
        for x in (0.25, 0.5, 99.0):
            h.observe(x)
        assert h.mean() == pytest.approx((0.25 + 0.5 + 99.0) / 3)

    def test_to_json_folds(self):
        h = Histogram(edges=(1.0, 2.0))
        h.observe(1.5)
        j = h.to_json()
        assert j["counts"] == [0, 1, 0]
        assert j["count"] == 1
        assert json.dumps(j)  # serializable


# ---------------------------------------------------------------------------
# EWMA + online cost tables
# ---------------------------------------------------------------------------
class TestEwma:
    def test_deferred_fold_matches_eager_recurrence(self):
        e = Ewma(alpha=0.3)
        xs = [5.0, 1.0, 2.0, 8.0, 3.0]
        for x in xs:
            e.observe(x)
        v = None
        for x in xs:  # the fold must replay in observation order
            v = x if v is None else 0.7 * v + 0.3 * x
        assert e.value == pytest.approx(v)
        assert e.count == len(xs)

    def test_converges_after_cost_drift(self):
        # the 0.9/0.1 EMA tracks a step change: after ~100 samples at the
        # new level the old level's weight is (0.9)^100 ~ 2.7e-5
        e = Ewma(alpha=0.1)
        for _ in range(50):
            e.observe(1.0)
        for _ in range(100):
            e.observe(2.0)
        assert e.value == pytest.approx(2.0, rel=1e-3)

    def test_seed_discards_pending(self):
        e = Ewma(alpha=0.1)
        e.observe(100.0)
        e.seed(3.0, 7)
        assert e.value == 3.0
        assert e.count == 7


class TestOnlineCostTable:
    def test_observe_and_cost_model_snapshot(self):
        t = OnlineCostTable(num_stages=2, alpha=0.5)
        t.observe(0, Kind.F, 2.0)
        t.observe(0, Kind.F, 4.0)
        t.observe(1, Kind.B, 3.0)
        t.observe_comm(1e-3)
        assert t.value(0, Kind.F) == pytest.approx(3.0)  # 0.5*2 + 0.5*4
        assert t.samples(0, Kind.F) == 2
        assert t.value(1, Kind.F) is None

        default = det_costs(2, f=9.0, b=9.0, w=0.5)
        cm = t.as_cost_model(default=default)
        assert cm.f_cost[0] == pytest.approx(3.0)
        assert cm.f_cost[1] == pytest.approx(9.0)  # unobserved -> fallback
        assert cm.b_cost[1] == pytest.approx(3.0)
        assert cm.w_cost[0] == pytest.approx(0.5)
        assert cm.comm_base == pytest.approx(1e-3)
        # jitter-free snapshot: realized variability is already in the EWMA
        assert cm.compute_jitter.sigma == 0.0

    def test_negative_comm_latency_dropped(self):
        t = OnlineCostTable(1)
        t.observe_comm(-1.0)
        assert t.comm.count == 0

    def test_update_from_trace_matches_manual_fold(self):
        spec = PipelineSpec(3, 4)
        cm = CostModel.uniform(3, seed=11)
        _, trace = run_recorded(spec, cm, mode="hint", hint=HintKind.BF,
                                seed=11)
        table = OnlineCostTable(spec.num_stages).update_from_trace(trace)

        expect: dict[tuple, Ewma] = {}
        sends, comm = {}, Ewma(0.1)
        for ev in trace.events:  # logical-clock order, like the table
            if ev.kind == _tr.COMPLETE and "dur" in ev.info:
                key = (ev.stage, ev.task.kind)
                expect.setdefault(key, Ewma(0.1)).observe(ev.info["dur"])
            elif ev.kind == _tr.SEND:
                sends.setdefault(ev.info["seq"], ev.t)
            elif ev.kind == _tr.DELIVER and ev.info.get("seq") in sends:
                comm.observe(ev.t - sends[ev.info["seq"]])
        assert expect  # the trace must carry durations
        for (s, k), e in expect.items():
            assert table.value(s, k) == pytest.approx(e.value)
            assert table.samples(s, k) == e.count
        assert table.comm.value == pytest.approx(comm.value)

    def test_to_json_serializable(self):
        t = OnlineCostTable(1)
        t.observe(0, Kind.F, 1.0)
        assert json.dumps(t.to_json())


# ---------------------------------------------------------------------------
# bubble decomposition
# ---------------------------------------------------------------------------
def assert_exact_attribution(report):
    """The non-negotiable invariant: categories sum to idle, per stage."""
    assert report.idle_fully_attributed()
    for sb in report.stages:
        assert sb.busy + sb.idle == pytest.approx(report.makespan)
        assert sb.attributed == pytest.approx(sb.idle, abs=1e-9)
        assert all(v >= -1e-12 for v in sb.bubbles.values())


class TestBubbleDecomposition:
    def test_chain_hint_idle_fully_attributed(self):
        spec = PipelineSpec(4, 6)
        _, trace = run_recorded(spec, det_costs(4), mode="hint",
                                hint=HintKind.BF, seed=3)
        report = decompose(trace)
        assert_exact_attribution(report)
        # the last stage fills late (warmup) and finishes its B early,
        # then sits idle while backward propagates to stage 0 (drain)
        assert report.stages[-1].bubbles["warmup"] > 0.0
        assert report.stages[-1].bubbles["drain"] > 0.0
        # stage 0 executes the final B of the run: no drain bubble there
        assert report.stages[0].bubbles["drain"] == 0.0

    def test_precommitted_1f1b_idle_fully_attributed(self):
        spec = PipelineSpec(4, 6)
        _, trace = run_recorded(spec, det_costs(4), mode="precommitted",
                                fixed_order="1f1b", seed=3)
        report = decompose(trace)
        assert_exact_attribution(report)

    def test_dag_with_jitter_idle_fully_attributed(self):
        spec = dag_spec(num_mb=4)
        cm = CostModel.uniform(spec.num_stages, seed=5)
        _, trace = run_recorded(spec, cm, mode="hint", hint=HintKind.BF,
                                seed=5)
        report = decompose(trace)
        assert_exact_attribution(report)

    def test_tp_degree_2_idle_fully_attributed(self):
        spec = PipelineSpec(3, 4)
        _, trace = run_recorded(spec, det_costs(3), mode="hint",
                                hint=HintKind.BF, seed=9, tp_degree=2)
        report = decompose(trace)
        assert_exact_attribution(report)

    def test_report_shapes_and_compare(self):
        spec = PipelineSpec(3, 6)
        _, slow = run_recorded(spec, det_costs(3), mode="precommitted",
                               fixed_order="gpipe", seed=1)
        _, fast = run_recorded(spec, det_costs(3), mode="hint",
                               hint=HintKind.BF, seed=1)
        base, other = decompose(slow), decompose(fast)
        j = base.to_json()
        assert set(j["category_totals"]) == set(CATEGORIES)
        assert json.dumps(j)
        assert "stage" in base.table()

        cmp = compare(base, other)
        assert cmp["speedup"] == pytest.approx(
            base.makespan / other.makespan)
        assert cmp["top_removed_category"] in CATEGORIES
        # the removed deltas are consistent with the two category totals
        bt, ot = base.category_totals(), other.category_totals()
        for c in CATEGORIES:
            assert cmp["removed"][c] == pytest.approx(bt[c] - ot[c])


# ---------------------------------------------------------------------------
# metrics wired into the runtime
# ---------------------------------------------------------------------------
class TestRuntimeMetrics:
    def test_metrics_never_change_decisions(self):
        # same seed, metrics on vs. off: identical event signature (the
        # info annotations metrics add are not part of the signature)
        for spec, kw in (
            (PipelineSpec(4, 6), dict(mode="hint", hint=HintKind.BF)),
            (dag_spec(4), dict(mode="hint", hint=HintKind.BF)),
            (PipelineSpec(4, 6, split_backward=True),
             dict(mode="hint", hint=HintKind.BFW, w_defer_cap=2)),
        ):
            cm = CostModel.uniform(spec.num_stages, seed=7)
            _, bare = run_recorded(spec, cm, seed=7, **kw)
            _, inst = run_recorded(spec, cm, seed=7,
                                   metrics=MetricsRegistry(), **kw)
            assert inst.signature() == bare.signature()

    def test_dispatch_and_mailbox_counts(self):
        spec = PipelineSpec(4, 6)
        reg = MetricsRegistry()
        cfg = ActorConfig(mode="hint", hint=HintKind.BF, seed=7, metrics=reg)
        ActorDriver(spec, det_costs(4), cfg).run()

        totals = reg.totals()
        assert sum(totals["dispatches"].values()) == spec.total_tasks()
        assert totals["dispatches"]["F"] == 4 * 6
        assert totals["dispatches"]["B"] == 4 * 6
        assert totals["dispatches"]["W"] == 0
        assert sum(totals["dispatch_paths"].values()) == spec.total_tasks()
        for sh in reg.shards():
            # everything buffered is eventually consumed; some dispatches
            # (the last stage's locally-enabled loss B) bypass the mailbox
            assert sum(sh.dequeues) == sum(sh.enqueues)
            assert sum(sh.dequeues) <= sum(sh.dispatches)
            assert sh.busy > 0.0
            assert sh.ready_depth.count == sum(sh.dispatches)
            # transport latency sampled once per message-completing envelope
            assert sh.comm_ewma.value is None or sh.comm_ewma.value >= 0.0
        # interior stages receive messages -> comm EWMAs populated
        assert reg.shards()[1].comm_ewma.count > 0
        assert json.dumps(reg.to_json())
        assert "total dispatches" in reg.report()

    def test_tp_gate_metrics(self):
        spec = PipelineSpec(3, 4)
        reg = MetricsRegistry()
        cfg = ActorConfig(mode="hint", hint=HintKind.BF, seed=9,
                          tp_degree=2, metrics=reg)
        ActorDriver(spec, det_costs(3), cfg).run()
        t = reg.totals()
        # every cross-stage message set needs both ranks: the first rank's
        # arrival holds, the second admits
        assert t["tp_admits"] > 0
        assert t["tp_holds"] > 0
        spread = sum(sh.tp_spread.count for sh in reg.shards())
        assert spread == t["tp_admits"]

    def test_wcap_and_backlog_metrics(self):
        spec = PipelineSpec(3, 6, split_backward=True)
        reg = MetricsRegistry()
        cfg = ActorConfig(mode="hint", hint=HintKind.BFW, seed=7,
                          w_defer_cap=1, metrics=reg)
        ActorDriver(spec, det_costs(3, w=1.0), cfg).run()
        t = reg.totals()
        assert t["dispatches"]["W"] == 3 * 6
        assert any(sh.w_backlog_peak > 0 for sh in reg.shards())

    def test_cost_table_snapshot_matches_shards(self):
        spec = PipelineSpec(3, 4)
        reg = MetricsRegistry()
        cfg = ActorConfig(mode="hint", hint=HintKind.BF, seed=5, metrics=reg)
        ActorDriver(spec, CostModel.uniform(3, seed=5), cfg).run()
        table = reg.cost_table()
        for sh in reg.shards():
            for k in (Kind.F, Kind.B):
                assert table.value(sh.stage, k) == pytest.approx(
                    sh.cost_ewma[k].value)
                assert table.samples(sh.stage, k) == sh.cost_ewma[k].count
        # snapshots feed hint synthesis as plain CostModels
        cm = table.as_cost_model()
        assert cm.f_cost.shape == (3,)

    def test_registry_accumulates_across_steps(self):
        spec = PipelineSpec(3, 4)
        reg = MetricsRegistry()
        for step in range(2):
            cfg = ActorConfig(mode="hint", hint=HintKind.BF, seed=step,
                              metrics=reg)
            ActorDriver(spec, det_costs(3), cfg).run()
        assert sum(reg.totals()["dispatches"].values()) == \
            2 * spec.total_tasks()

    def test_shard_auto_extends(self):
        reg = MetricsRegistry()
        assert reg.num_stages == 0
        reg.shard(3).on_dequeue(Kind.F)
        assert reg.num_stages == 4

    def test_divergence_slots(self):
        spec = PipelineSpec(4, 6)
        reg = MetricsRegistry()
        cfg = ActorConfig(mode="hint", hint=HintKind.BF, seed=7, metrics=reg)
        ActorDriver(spec, det_costs(4), cfg).run()
        for sh in reg.shards():
            # every hint-path dispatch lands in exactly one slot
            assert sum(sh.divergence) == sh.dispatch_paths["hint"]
            assert sh.hint_divergences() == sum(sh.divergence[1:])


class TestMetricsRecordReplay:
    def test_metrics_annotated_trace_replays_exactly(self, tmp_path):
        spec = PipelineSpec(4, 6)
        cm = CostModel.uniform(4, seed=13)
        _, trace = run_recorded(spec, cm, mode="hint", hint=HintKind.BF,
                                seed=13, metrics=MetricsRegistry())
        # the metrics annotations (ewma on COMPLETE, slot on DISPATCH)
        # survive the save/load roundtrip ...
        path = tmp_path / "trace.jsonl"
        trace.save(str(path))
        loaded = Trace.load(str(path))
        assert loaded.signature() == trace.signature()
        assert any("ewma" in ev.info for ev in loaded.events
                   if ev.kind == _tr.COMPLETE)
        # ... and the replay oracle tolerates them (time-exact sim replay)
        rdriver = ActorDriver(
            spec, None, ActorConfig(record_trace=True, replay=loaded))
        rdriver.run()
        assert rdriver.trace.signature(include_time=True) == \
            trace.signature(include_time=True)

    def test_durations_keyed_by_full_identity(self):
        spec = PipelineSpec(3, 4, split_backward=True)
        cm = det_costs(3).with_split_backward()
        _, trace = run_recorded(spec, cm, mode="hint", hint=HintKind.BFW,
                                seed=7)
        durs = trace.durations()
        # no collapsing across kind/stage/mb: one entry per task
        assert len(durs) == spec.total_tasks()
        # duplicate COMPLETEs keep the first duration
        ev = next(e for e in trace.events
                  if e.kind == _tr.COMPLETE and "dur" in e.info)
        forged = Trace(meta=dict(trace.meta), events=list(trace.events))
        forged.events.append(_tr.TraceEvent(
            lc=10**9, kind=_tr.COMPLETE, stage=ev.stage, task=ev.task,
            t=ev.t, info={"dur": ev.info["dur"] + 123.0}))
        assert forged.durations() == durs


# ---------------------------------------------------------------------------
# adaptive-loop inputs: EWMA properties, epoch hygiene, recovery downweight
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback (tests/_hyp_stub.py)
    from _hyp_stub import given, settings, strategies as st


class TestEwmaFoldProperty:
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
           n=st.integers(min_value=1, max_value=64))
    @settings(max_examples=25, deadline=None)
    def test_deferred_equals_eager_for_any_sequence(self, seed, n):
        # the lazy-fold observe path must be observationally identical to
        # the textbook recurrence for *every* sample sequence, not just the
        # handful of fixtures above — the adaptive re-synthesizer trusts
        # these values as its measured cost model
        import numpy as _np

        xs = _np.random.default_rng(seed).exponential(size=n) + 1e-9
        e = Ewma(alpha=0.1)
        for x in xs:
            e.observe(float(x))
        v = None
        for x in xs:
            v = float(x) if v is None else 0.9 * v + 0.1 * float(x)
        assert e.value == pytest.approx(v)
        assert e.count == n

    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_interleaved_observe_seed_read(self, seed):
        # reads force a fold of the pending samples; folding mid-stream
        # must leave the same state as never having read at all, and seed
        # must discard whatever was pending at that point
        import numpy as _np

        rng = _np.random.default_rng(seed)
        e = Ewma(alpha=0.1)
        v, c = None, 0
        for _ in range(40):
            op = int(rng.integers(4))
            if op == 0:
                x = float(rng.exponential()) + 1e-9
                e.observe(x)
                v = x if v is None else 0.9 * v + 0.1 * x
                c += 1
            elif op == 1:
                x, n = float(rng.uniform(0.1, 10.0)), int(rng.integers(20))
                e.seed(x, n)
                v, c = x, n
            elif op == 2:
                assert (e.value is None) == (v is None)
                if v is not None:
                    assert e.value == pytest.approx(v)
            else:
                assert e.count == c
        if v is None:
            assert e.value is None
        else:
            assert e.value == pytest.approx(v)
        assert e.count == c

    def test_downweight_keeps_value_collapses_count(self):
        e = Ewma(alpha=0.1)
        for x in (1.0, 2.0, 3.0):
            e.observe(x)
        v = e.value
        e.downweight(keep=1)
        assert e.value == pytest.approx(v)
        assert e.count == 1
        e.downweight(keep=0)
        assert e.count == 0

    def test_downweight_empty_is_noop(self):
        e = Ewma(alpha=0.1)
        e.downweight()
        assert e.value is None and e.count == 0

    def test_downweight_never_raises_count(self):
        e = Ewma(alpha=0.1)
        e.observe(5.0)
        e.downweight(keep=100)
        assert e.count == 1


class TestEpochAwareTraceFold:
    """update_from_trace's recovery hygiene on a hand-built trace."""

    def _ev(self, lc, kind, stage=0, t=0.0, epoch=0, **info):
        return _tr.TraceEvent(lc=lc, kind=kind, stage=stage,
                              task=Task(Kind.F, stage, 0),
                              t=t, epoch=epoch, info=info)

    def test_same_epoch_pair_feeds_comm(self):
        trace = Trace(meta={}, events=[
            self._ev(0, _tr.SEND, t=1.0, seq=7),
            self._ev(1, _tr.DELIVER, stage=1, t=1.5, seq=7),
        ])
        table = OnlineCostTable(2).update_from_trace(trace)
        assert table.comm.value == pytest.approx(0.5)
        assert table.comm.count == 1

    def test_cross_epoch_pair_excluded(self):
        # SEND in epoch 0, DELIVER in epoch 1: the gap spans the recovery
        # outage, not the transport — must not poison the comm EWMA
        trace = Trace(meta={}, events=[
            self._ev(0, _tr.SEND, t=1.0, seq=7),
            self._ev(1, _tr.RECOVERY_END, stage=1, t=5.0, epoch=1),
            self._ev(2, _tr.DELIVER, stage=1, t=6.0, epoch=1, seq=7),
        ])
        table = OnlineCostTable(2).update_from_trace(trace)
        assert table.comm.count == 0

    def test_fenced_seq_excluded(self):
        # a FENCEd envelope was rejected by the mailbox as stale; even if
        # a same-epoch DELIVER for that seq exists it is not a latency
        # sample
        trace = Trace(meta={}, events=[
            self._ev(0, _tr.SEND, t=1.0, seq=9),
            self._ev(1, _tr.FENCE, stage=1, t=2.0, seq=9),
            self._ev(2, _tr.DELIVER, stage=1, t=2.0, seq=9),
        ])
        table = OnlineCostTable(2).update_from_trace(trace)
        assert table.comm.count == 0

    def test_mixed_trace_counts_only_clean_pairs(self):
        trace = Trace(meta={}, events=[
            self._ev(0, _tr.SEND, t=0.0, seq=1),
            self._ev(1, _tr.DELIVER, stage=1, t=0.25, seq=1),   # clean
            self._ev(2, _tr.SEND, t=1.0, seq=2),
            self._ev(3, _tr.FENCE, stage=1, t=1.1, seq=2),      # fenced
            self._ev(4, _tr.DELIVER, stage=1, t=1.1, seq=2),
            self._ev(5, _tr.SEND, t=2.0, seq=3),
            self._ev(6, _tr.DELIVER, stage=1, t=9.0, epoch=1, seq=3),
            self._ev(7, _tr.COMPLETE, t=3.0, dur=1.5),          # durations
        ])                                                      # unaffected
        table = OnlineCostTable(2).update_from_trace(trace)
        assert table.comm.count == 1
        assert table.comm.value == pytest.approx(0.25)
        assert table.samples(0, Kind.F) == 1
        assert table.value(0, Kind.F) == pytest.approx(1.5)

    def test_recovered_run_end_to_end_excludes_outage(self):
        # a real fail-stop run: every comm sample the table folded must be
        # small (transport-scale), never recovery-outage-scale
        from repro.runtime.rrfp.chaos import ChaosConfig

        spec = PipelineSpec(3, 6)
        cm = det_costs(3)
        cfg = ActorConfig(
            mode="hint", hint=HintKind.BF, record_trace=True,
            chaos=ChaosConfig(fail_stage=1, fail_after=4),
            recover=True, restore_cost=0.05)
        driver = ActorDriver(spec, cm, cfg)
        driver.run()
        trace = driver.trace
        assert trace.select(_tr.RECOVERY_END), "recovery never happened"
        table = OnlineCostTable(3).update_from_trace(trace)
        assert table.comm.count > 0
        # outage-spanning pairs would be >= restore_cost; clean transport
        # latencies on this workload are ~comm_base
        assert table.comm.value < 0.05


class TestRegistryRecovery:
    def test_logical_stage_keying_survives_remap(self):
        # shards are keyed by logical stage: observations for stage 2 land
        # in shard 2 no matter which incarnation/host reported them
        reg = MetricsRegistry(3)
        reg.shard(2).on_complete(Task(Kind.F, 2, 0), 1.0)
        reg.on_recovery(2)
        reg.shard(2).on_complete(Task(Kind.F, 2, 1), 3.0)
        assert reg.shard(2) is reg._shards[2]
        assert reg.cost_table().samples(2, Kind.F) == 2

    def test_on_recovery_downweights_stage_ewmas(self):
        reg = MetricsRegistry(2)
        sh = reg.shard(1)
        for _ in range(50):
            sh.on_complete(Task(Kind.B, 1, 0), 4.0)
        sh.comm_ewma.observe(0.1)
        sh.comm_ewma.observe(0.1)
        reg.on_recovery(1, keep=1)
        assert sh.cost_ewma[Kind.B].value == pytest.approx(4.0)
        assert sh.cost_ewma[Kind.B].count == 1
        assert sh.comm_ewma.count == 1
        # the recurrence itself is untouched; what collapses is the sample
        # *weight* — cost_table() snapshots seed with (value, count), so a
        # post-recovery merge sees a 1-sample prior, not 50 stale votes
        sh.on_complete(Task(Kind.B, 1, 1), 8.0)
        assert sh.cost_ewma[Kind.B].count == 2
        assert sh.cost_ewma[Kind.B].value == pytest.approx(4.4)

    def test_on_recovery_unknown_stage_is_noop(self):
        reg = MetricsRegistry(2)
        reg.on_recovery(7)  # never observed; must not create a shard
        assert 7 not in reg._shards
