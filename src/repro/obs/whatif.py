"""Coz-style causal what-if profiling over the critical-path graph.

A :class:`Speedup` is a *virtual* speedup — "what if this op class / this
stage / the message latencies were ``factor``× as expensive" — applied to
the :class:`~repro.obs.critpath.ExecGraph` recurrence rather than to the
system.  :func:`predict` re-runs the generative recurrence with scaled
durations (``dur * factor`` for matching compute nodes) or scaled
SEND->DELIVER latencies (``comm * factor``), holding everything the
speedup does not touch — dispatch residuals, gate residuals, coordination,
and the recorded dependency structure — fixed.  The answer is what Coz
calls a causal profile: the *predicted* makespan if only that one thing
got faster, with zero re-execution.

Two deliberate exactness properties:

* ``factor == 1.0`` regenerates the recorded makespan (to ~1e-9 relative —
  the recurrence is :meth:`ExecGraph.verify`'s identity);
* **recovery windows are pinned**: a recovery node's completion stays at
  its *recorded* RECOVERY_END regardless of upstream speedups, so MTTR is
  attributed, never "sped up" — detection deadlines and restore costs do
  not shrink because a kernel got faster (the recovery-aware mirror of the
  cost table's epoch-aware EWMA hygiene).

:func:`apply_to_cost_model` maps the same speedup spec onto a
:class:`~repro.core.costs.CostModel` so a benchmark can *realize* the
speedup in an actual DES rerun and gate predicted-vs-realized error
(``benchmarks/critical_path.py`` -> ``BENCH_critpath.json``).
"""
from __future__ import annotations

import dataclasses

from repro.core.costs import CostModel

from repro.obs.critpath import ROOT_KEY, ExecGraph

#: op-class label -> CostModel rows it scales (dX/dW are the split-backward
#: names of the B/W rows)
_OP_ROWS = {"F": ("f",), "B": ("b",), "dX": ("b",), "W": ("w",),
            "dW": ("w",)}


@dataclasses.dataclass(frozen=True)
class Speedup:
    """One virtual speedup: scale an op class, a stage, or comm latency.

    ``factor`` multiplies the matched durations (0.5 = twice as fast,
    2.0 = twice as slow — virtual slowdowns are valid what-ifs too).
    ``op`` and ``stage`` compose conjunctively ("dX on stage 2"); ``comm``
    is its own edge-latency class and ignores both.
    """

    factor: float
    op: str | None = None      # "F" / "B" / "W" / "dX" / "dW"
    stage: int | None = None
    comm: bool = False

    def __post_init__(self):
        if not (self.factor > 0.0):
            raise ValueError(f"factor must be > 0, got {self.factor}")
        if self.comm and (self.op is not None or self.stage is not None):
            raise ValueError("comm speedups scale edge latency only; "
                             "op/stage do not apply")
        if self.op is not None and self.op not in _OP_ROWS:
            raise ValueError(f"unknown op class {self.op!r}")

    def describe(self) -> str:
        if self.comm:
            return f"comm x{self.factor:g}"
        parts = []
        if self.op is not None:
            parts.append(self.op)
        if self.stage is not None:
            parts.append(f"stage {self.stage}")
        return f"{' @ '.join(parts) or 'compute'} x{self.factor:g}"

    def matches(self, op: str, stage: int) -> bool:
        if self.comm:
            return False
        if self.op is not None and op != self.op:
            return False
        if self.stage is not None and stage != self.stage:
            return False
        return True


def predict_ends(graph: ExecGraph,
                 speedups: list[Speedup]) -> dict[tuple, float]:
    """Per-node predicted completion under the virtual speedups."""
    comm_scale = 1.0
    for s in speedups:
        if s.comm:
            comm_scale *= s.factor
    ends: dict[tuple, float] = {ROOT_KEY: 0.0}
    for key in graph.order:
        if key == ROOT_KEY:
            continue
        n = graph.nodes[key]
        if n.op == "recovery":
            # MTTR is pinned: the outage ends when it ended
            ends[key] = n.end_t
            continue
        arr = max((ends.get(e.src, graph.nodes[e.src].end_t)
                   + e.comm * comm_scale + e.gate
                   for e in n.in_edges), default=0.0)
        dur = n.dur
        for s in speedups:
            if s.matches(n.op, n.stage):
                dur *= s.factor
        ends[key] = arr + n.residual + n.coord + dur
    return ends


def predict(graph: ExecGraph, speedups: list[Speedup]) -> float:
    """Predicted makespan under the virtual speedups (no re-execution)."""
    ends = predict_ends(graph, speedups)
    return max(ends.values(), default=0.0)


def apply_to_cost_model(cm: CostModel,
                        speedups: list[Speedup]) -> CostModel:
    """Realize the speedups in a cost model (for a validating DES rerun).

    Compute speedups scale the matching base-cost rows (jitter is
    multiplicative, so CRN-seeded realized durations scale exactly
    proportionally); comm speedups scale ``comm_base``.
    """
    f = cm.f_cost.copy()
    b = cm.b_cost.copy()
    w = cm.w_cost.copy()
    rows = {"f": f, "b": b, "w": w}
    comm = cm.comm_base
    for s in speedups:
        if s.comm:
            comm *= s.factor
            continue
        names = _OP_ROWS[s.op] if s.op is not None else ("f", "b", "w")
        idx = slice(None) if s.stage is None else s.stage
        for name in names:
            rows[name][idx] = rows[name][idx] * s.factor
    return dataclasses.replace(cm, f_cost=f, b_cost=b, w_cost=w,
                               comm_base=comm)


def candidate_speedups(graph: ExecGraph,
                       factor: float = 0.75) -> list[Speedup]:
    """The default what-if sweep: each op class present on the graph, each
    stage's compute, and the comm edge-latency class."""
    ops = sorted({n.op for n in graph.nodes.values()
                  if n.task is not None})
    stages = sorted({n.stage for n in graph.nodes.values()
                     if n.task is not None})
    out = [Speedup(factor=factor, op=op) for op in ops]
    out += [Speedup(factor=factor, stage=s) for s in stages]
    out.append(Speedup(factor=factor, comm=True))
    return out


def rank(graph: ExecGraph, speedups: list[Speedup] | None = None,
         factor: float = 0.75) -> list[dict]:
    """Rank virtual speedups by predicted makespan gain (best first)."""
    base = graph.makespan
    out = []
    for s in (speedups if speedups is not None
              else candidate_speedups(graph, factor)):
        p = predict(graph, [s])
        out.append({
            "speedup": s.describe(),
            "op": s.op, "stage": s.stage, "comm": s.comm,
            "factor": s.factor,
            "predicted_makespan": p,
            "gain": base - p,
            "gain_frac": (base - p) / base if base else 0.0,
        })
    out.sort(key=lambda r: -r["gain"])
    return out
