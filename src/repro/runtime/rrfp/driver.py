"""Actor-runtime driver: builds the actors, pumps messages, records traces.

Two execution substrates behind one configuration:

* ``run()`` — :class:`~repro.runtime.rrfp.transport.SimTransport` on a
  virtual clock.  Arrivals and completions are heap events; actors make
  every dispatch decision reactively (no schedule-table tick).  Compute and
  communication samples are keyed per task (common random numbers), so hint
  vs. precommitted runs on the same seed experience the same realized
  variability — the paper's one-schedule-two-consumption-modes contrast
  isolated from sampling noise.

* ``run_threaded(work_fn)`` — thread-per-stage actors over the
  :class:`~repro.runtime.rrfp.transport.ThreadTransport`, executing real
  work callables (e.g. jitted stage functions from
  ``repro.pipeline.stagefn``) on the wall clock.

Both return the DES engine's :class:`~repro.core.engine.RunResult`, so
``benchmarks/``, the Theorem 6.1 bound checker and
``runtime.straggler`` consume actor traces unchanged.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import zlib
from typing import Any, Callable

import numpy as np

from repro.core.costs import CostModel
from repro.core.engine import DeadlockError, RunResult, StageStats
from repro.core.hints import FIXED_ORDERS, HintKind
from repro.core.taskgraph import Kind, PipelineSpec, Task

from repro.runtime.rrfp.actor import StageActor
from repro.runtime.rrfp.mailbox import Mailbox
from repro.runtime.rrfp.messages import Envelope, envelopes_for
from repro.runtime.rrfp.transport import SimTransport, ThreadTransport


@dataclasses.dataclass
class ActorConfig:
    """Runtime configuration (mirrors ``EngineConfig`` where they overlap)."""

    mode: str = "hint"  # "hint" (RRFP) | "precommitted" (fixed-order baselines)
    hint: HintKind = HintKind.BF
    fixed_order: str = "1f1b"  # precommitted mode: key into FIXED_ORDERS
    custom_orders: list[list[Task]] | None = None  # overrides fixed_order
    buffer_limit: int = 32  # App. C backpressure limit
    #: BFW: max outstanding un-executed W tasks per stage (each holds one
    #: stashed (x, g_in) activation pair); 0 = unbounded deferral
    w_defer_cap: int = 0
    tp_degree: int = 1
    tp_coord_base: float = 75e-6  # scalar all-gather cost (Table 3)
    seed: int = 0
    #: thread mode: seconds of mailbox starvation before DeadlockError
    deadlock_timeout: float = 30.0


def _compute_rng(seed: int, task: Task) -> np.random.Generator:
    return np.random.default_rng(
        [seed & 0x7FFFFFFF, zlib.crc32(b"rrfp-compute"),
         int(task.kind), task.stage, task.mb, task.chunk])


class ActorDriver:
    """One training iteration through the actor runtime."""

    def __init__(self, spec: PipelineSpec, costs: CostModel | None,
                 config: ActorConfig):
        if costs is not None and costs.num_stages != spec.num_stages:
            raise ValueError("cost model / spec stage mismatch")
        if (spec.split_backward and config.mode == "hint"
                and config.hint != HintKind.BFW):
            raise ValueError(
                f"hint mode on a split-backward spec requires HintKind.BFW "
                f"(got {config.hint}): only the BFW hint dispatches W tasks")
        self.spec = spec
        self.costs = costs
        self.config = config

    # ------------------------------------------------------------------
    def _build_actors(self) -> tuple[list[Mailbox], list[StageActor]]:
        spec, cfg = self.spec, self.config
        mailboxes, actors = [], []
        for s in range(spec.num_stages):
            order = None
            if cfg.mode == "precommitted":
                if cfg.custom_orders is not None:
                    order = cfg.custom_orders[s]
                else:
                    order = FIXED_ORDERS[cfg.fixed_order](spec, s)
            mb = Mailbox(s, cfg.tp_degree)
            mailboxes.append(mb)
            actors.append(StageActor(
                s, spec, mb, mode=cfg.mode, hint=cfg.hint, order=order,
                buffer_limit=cfg.buffer_limit, w_defer_cap=cfg.w_defer_cap))
        return mailboxes, actors

    def _seed_inputs(self, mailboxes: list[Mailbox]) -> None:
        """Stage 0 / chunk 0 forward inputs are locally available at t=0."""
        for j in range(self.spec.num_microbatches):
            mailboxes[0].deliver_local(Task(Kind.F, 0, j, 0))

    # ---- simulation substrate -----------------------------------------
    def run(self) -> RunResult:
        if self.costs is None:
            raise ValueError("simulation mode requires a CostModel")
        spec, cfg, costs = self.spec, self.config, self.costs
        mailboxes, actors = self._build_actors()

        events: list = []  # (time, seq, kind, payload)
        seq = 0

        def push(t: float, ekind: str, payload) -> None:
            nonlocal seq
            heapq.heappush(events, (t, seq, ekind, payload))
            seq += 1

        transport = SimTransport(
            costs, schedule=lambda t, env: push(t, "deliver", env),
            seed=cfg.seed)
        inj_states = [costs.injection.make_state() for _ in range(spec.num_stages)]
        busy_until = [0.0] * spec.num_stages
        idle_since = [0.0] * spec.num_stages
        start: dict[Task, float] = {}
        end: dict[Task, float] = {}
        n_done = 0
        total = spec.total_tasks()

        self._seed_inputs(mailboxes)
        for a in actors:
            a.sync_mailbox()

        def try_dispatch(s: int, now: float) -> None:
            actor = actors[s]
            if busy_until[s] > now:
                return
            task = actor.select()
            if task is None:
                return
            actor.begin(task)
            coord = mailboxes[s].group.coordination_cost(task, cfg.tp_coord_base)
            rng = _compute_rng(cfg.seed, task)
            dur = costs.sample_compute(task.kind, s, task.mb, rng)
            if task.kind != Kind.W:
                dur += costs.injection.sample_delay(inj_states[s], dur, rng)
            actor.stats.blocking += max(0.0, now - idle_since[s])
            actor.stats.tp_coord += coord
            actor.stats.compute += dur
            begin = now + coord
            start[task] = begin
            busy_until[s] = begin + dur
            push(busy_until[s], "complete", task)

        for s in range(spec.num_stages):
            try_dispatch(s, 0.0)

        while events:
            now, _, ekind, payload = heapq.heappop(events)
            if ekind == "complete":
                task: Task = payload
                s = task.stage
                end[task] = now
                n_done += 1
                succ = actors[s].complete(task)
                if succ is not None:
                    for env in envelopes_for(succ, s, cfg.tp_degree,
                                             send_time=now):
                        transport.send(env, now=now)
                idle_since[s] = now
                try_dispatch(s, now)
            else:  # deliver
                env: Envelope = payload
                s = env.dst_stage
                adm = mailboxes[s].deliver(env, now=now)
                if adm is not None:
                    actors[s].sync_mailbox()
                    try_dispatch(s, now)

        if n_done != total:
            starved = {
                a.idx: a.waiting_on()[:4] for a in actors if not a.finished()
            }
            raise DeadlockError(
                f"actor runtime stalled with {total - n_done} tasks "
                f"unexecuted (mode={cfg.mode}); starved stages -> first "
                f"missing messages: {starved}")
        makespan = max(end.values())
        for s, a in enumerate(actors):
            a.stats.blocking += max(0.0, makespan - busy_until[s])
            a.stats.deferrals = mailboxes[s].group.deferrals
        return RunResult(
            makespan=makespan,
            stage_stats=[a.stats for a in actors],
            start=start,
            end=end,
            spec=spec,
        )

    # ---- thread-per-stage substrate ------------------------------------
    def run_threaded(
        self,
        work_fn: Callable[[Task, Any], Any] | list[Callable[[Task, Any], Any]],
    ) -> RunResult:
        """Drive real per-stage callables with thread actors (wall clock).

        ``work_fn(task, payload)`` (or one callable per stage) performs the
        actual computation and returns the payload for the outgoing message.
        """
        import time as _time

        spec, cfg = self.spec, self.config
        mailboxes, actors = self._build_actors()
        transport = ThreadTransport({m.stage: m for m in mailboxes})
        work_fns = (work_fn if isinstance(work_fn, list)
                    else [work_fn] * spec.num_stages)
        t0 = _time.perf_counter()
        clock = lambda: _time.perf_counter() - t0  # noqa: E731
        abort = threading.Event()
        errors: list[BaseException] = []

        def runner(actor: StageActor):
            try:
                actor.run_thread(
                    work_fns[actor.idx], transport, clock,
                    tp_degree=cfg.tp_degree,
                    deadlock_timeout=cfg.deadlock_timeout,
                    abort=abort,
                    poll=min(0.05, cfg.deadlock_timeout / 4),
                )
            except BaseException as e:  # noqa: BLE001 - reraised on join
                errors.append(e)
                abort.set()

        self._seed_inputs(mailboxes)
        threads = [
            threading.Thread(target=runner, args=(a,), name=f"stage-{a.idx}",
                             daemon=True)
            for a in actors
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for m in mailboxes:
            m.stop()
        if errors:
            raise errors[0]
        start = {tr.task: tr.start for a in actors for tr in a.traces}
        end = {tr.task: tr.end for a in actors for tr in a.traces}
        if len(end) != spec.total_tasks():
            raise DeadlockError(
                f"threaded run finished {len(end)}/{spec.total_tasks()} tasks")
        makespan = max(end.values())
        for a in actors:
            a.stats.blocking += max(
                0.0, makespan - max(tr.end for tr in a.traces))
            a.stats.deferrals = a.mailbox.group.deferrals
        return RunResult(
            makespan=makespan,
            stage_stats=[a.stats for a in actors],
            start=start,
            end=end,
            spec=spec,
        )


# --------------------------------------------------------------------------
def run_actor_iteration(
    spec: PipelineSpec, costs: CostModel, config: ActorConfig
) -> RunResult:
    return ActorDriver(spec, costs, config).run()


def average_makespan_actor(
    spec: PipelineSpec,
    costs: CostModel,
    config: ActorConfig,
    iters: int = 10,
) -> tuple[float, float, list[RunResult]]:
    """Mean/std of makespan over independently-seeded iterations (CRN per seed)."""
    results = []
    for i in range(iters):
        cfg = dataclasses.replace(config, seed=config.seed + 1000 * i)
        results.append(ActorDriver(spec, costs, cfg).run())
    xs = np.array([r.makespan for r in results])
    return float(xs.mean()), float(xs.std()), results
