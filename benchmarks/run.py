# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: engine-level reproduction of every paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [table1 table6 ...]
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks.paper_tables import ALL_TABLES

    wanted = sys.argv[1:] or list(ALL_TABLES)
    print("name,us_per_call,derived")
    for name in wanted:
        fn = ALL_TABLES[name]
        t0 = time.time()
        rows = fn()
        dt = time.time() - t0
        for row_name, us, derived in rows:
            print(f"{row_name},{us:.1f},{derived}")
        print(f"# {name} done in {dt:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
