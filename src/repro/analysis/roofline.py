"""Three-term roofline analysis per (arch × shape × mesh) cell.

Terms (per device, seconds; TPU v5e constants):
  compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16)
  memory     = HLO_bytes / HBM_bw              (819 GB/s)
  collective = wire_bytes / link_bw            (50 GB/s per ICI link)

``HloCostAnalysis`` counts while bodies once (verified; DESIGN §6), so FLOPs
and bytes are assembled from *standalone lowered per-op programs* (the exact
F/B bodies the executor switches into, at per-device local shapes) multiplied
by the schedule's op counts — trip-count-exact by construction.  Collective
bytes follow the executor's issue pattern analytically (it is our code), and
are cross-checked against the collective ops visible in the compiled HLO.

Also derives a static step-time estimate: sum over ticks of the slowest
stage's op time (plus non-overlapped reduction/optimizer tails), giving the
projected MFU used as the hillclimbing score in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.cells import CellPlan, plan_cell
from repro.models.build import ArchModel
from repro.pipeline.executor import ExecOptions, chunked_ce_sum, _ce_chunk
from repro.pipeline.spec import OP_B, OP_F, ScheduleTable

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256  # single-pod roofline (16×16)


# ---------------------------------------------------------------------------
# per-op standalone costing
# ---------------------------------------------------------------------------
def _cost(fn, *args) -> dict[str, float]:
    c = jax.jit(fn).lower(*args).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0) or 0.0),
            "bytes": float(ca.get("bytes accessed", 0.0) or 0.0)}


def per_op_costs(plan: CellPlan, opts: ExecOptions | None = None) -> dict:
    """FLOPs/bytes of each schedule-op body at per-device local shapes.

    Stage archetypes: first (embed+layers), mid (layers), last (layers+CE).
    MoE collectives are replaced by their local-compute equivalents for
    costing (collective FLOPs are ~0; wire bytes are modeled separately).
    """
    model = plan.model
    cfg = model.cfg
    eff_seq = plan.seq_len + (plan.enc_len if cfg.encoder_layers else 0)
    mb = plan.mb_rows
    d = cfg.d_model
    key = jax.random.key(0)
    sp1 = jax.eval_shape(
        lambda k: jax.tree.map(lambda x: x[0], model.init_stage_params(k)), key)
    io = jax.eval_shape(model.init_io_params, key)
    x = jax.ShapeDtypeStruct((mb, eff_seq, d), cfg.dtype)
    g = jax.ShapeDtypeStruct((mb, eff_seq, d), cfg.dtype)
    tokens = jax.ShapeDtypeStruct((mb, plan.seq_len), jnp.int32)
    aux: dict[str, Any] = {
        "positions": jnp.broadcast_to(
            jnp.arange(eff_seq, dtype=jnp.int32)[None], (mb, eff_seq)),
        "data_size": 16,
        "moe_layout": "none",  # collectives modeled analytically
    }
    if cfg.mrope:
        aux["mrope"] = jnp.broadcast_to(
            jnp.arange(eff_seq, dtype=jnp.int32)[None, None], (3, mb, eff_seq))
    if cfg.encoder_layers:
        aux["dec_len"] = plan.seq_len
    rows_first = model.rows(0)
    rows_last = model.rows(model.num_stages - 1)
    ce_chunk = _ce_chunk(model, opts) if opts else max(
        64, min(2048, (1 << 24) // cfg.padded_vocab() * 4))

    def fwd(sp, io_, x):
        return model.stage_forward(sp, io_, x, aux, rows_first)

    def embed(io_, tokens):
        e = io_["embed"][tokens]
        if cfg.encoder_layers:
            e = jnp.concatenate(
                [e, jnp.zeros((mb, plan.enc_len, d), cfg.dtype)], axis=1)
        return e

    def ce(io_, y, labels):
        if cfg.encoder_layers:
            y = y[:, : plan.seq_len]
        return chunked_ce_sum(model, io_, y, labels, ce_chunk)

    out: dict[str, dict] = {}
    out["F"] = _cost(fwd, sp1, io, x)
    out["embed"] = _cost(embed, io, tokens)
    out["ce"] = _cost(ce, io, x, tokens)

    if plan.step == "train":
        def bwd_mid(sp, io_, x, g):
            def s(sp, io_, x):
                y = model.stage_forward(sp, io_, x, aux, rows_first)
                return jnp.sum(y.astype(jnp.float32) * g.astype(jnp.float32))
            return jax.grad(s, argnums=(0, 1, 2))(sp, io_, x)

        def bwd_last(sp, io_, x, labels):
            def s(sp, io_, x):
                y = model.stage_forward(sp, io_, x, aux, rows_last)
                return ce(io_, y, labels)
            return jax.grad(s, argnums=(0, 1, 2))(sp, io_, x)

        out["B"] = _cost(bwd_mid, sp1, io, x, g)
        out["B_last"] = _cost(bwd_last, sp1, io, x, tokens)
    else:
        x1 = jax.ShapeDtypeStruct((mb, 1, d), cfg.dtype)
        cache = jax.eval_shape(
            lambda: jax.tree.map(
                lambda l: jnp.stack([l] * model.l_max),
                model.init_layer_cache(
                    mb if not plan.sp_mode else plan.cell.global_batch,
                    plan.cell.seq_len // (plan.dp_total if plan.sp_mode else 1),
                    enc_len=max(1, plan.enc_len))))
        daux = {"data_size": 16, "moe_layout": "none"}

        def dec(sp, io_, x, cache):
            return model.stage_decode(sp, io_, x, cache,
                                      jnp.asarray(0, jnp.int32), daux,
                                      rows_first)

        out["F_dec"] = _cost(dec, sp1, io, x1, cache)
    return out


# ---------------------------------------------------------------------------
# collective model (wire bytes per device per step)
# ---------------------------------------------------------------------------
def collective_bytes(plan: CellPlan, table: ScheduleTable | None) -> dict:
    cfg = plan.model.cfg
    model = plan.model
    d = cfg.d_model
    n = 16  # data ring
    eff_seq = plan.seq_len + (plan.enc_len if cfg.encoder_layers else 0)
    mb_bytes = plan.mb_rows * (eff_seq if plan.step == "train" else 1) * d * 2
    out = {"permute": 0.0, "grad_rs": 0.0, "param_ag": 0.0, "io_ar": 0.0,
           "moe": 0.0, "sp": 0.0}
    if plan.step == "train":
        T = table.num_ticks
        out["permute"] = 2.0 * T * mb_bytes  # act fwd + grad bwd rings
        n_stage = (cfg.param_count(include_embed=False) - d) / model.num_stages
        n_io = 2 * cfg.padded_vocab() * d
        expert = 0.0
        if cfg.moe is not None:
            moe_layers = sum(1 for k in cfg.pattern if k == "moe")
            expert = (moe_layers / cfg.num_layers) * n_stage * 0.9
        repl = n_stage - expert
        out["grad_rs"] = (repl + n_io) * 2 * (n - 1) / n
        out["param_ag"] = (repl + n_io) * 2 * (n - 1) / n
        out["io_ar"] = n_io * 2 * 2 * (n - 1) / n  # psum over model of io grads
        if cfg.moe is not None:
            M = table.spec.num_microbatches
            tokens = plan.mb_rows * plan.seq_len
            cap_bytes = (tokens * cfg.moe.top_k * cfg.moe.capacity_factor
                         * d * 2)
            moe_layers_per_stage = sum(
                1 for k in cfg.pattern if k == "moe") / model.num_stages
            per_op = 2 * cap_bytes * (n - 1) / n  # a2a there+back / AG+RS
            # F issues the pair once; B only transposes it (the dispatched
            # buffers are checkpoint-policy-saved, so remat re-issues none)
            out["moe"] = M * moe_layers_per_stage * per_op * 2
    else:
        T = plan.num_microbatches + model.num_stages - 1
        out["permute"] = T * mb_bytes
        if plan.sp_mode:
            # distributed flash-decode psums per attention layer
            attn_slots = int((model.type_ids >= 0).sum()) / model.num_stages
            kv = cfg.num_kv_heads * cfg.resolved_head_dim
            out["sp"] = attn_slots * 2 * (n - 1) / n * (
                plan.cell.global_batch * cfg.num_heads
                * cfg.resolved_head_dim * 4)
    out["total"] = sum(out.values())
    return out


# ---------------------------------------------------------------------------
# cell roofline
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    schedule: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_device: float
    useful_ratio: float
    est_step_s: float
    projected_mfu: float
    notes: str = ""

    def as_dict(self):
        return dataclasses.asdict(self)


class ProductionMeshShape:
    """Lightweight stand-in: plan_cell only reads ``mesh.shape`` — the
    roofline never allocates devices."""

    def __init__(self, multi_pod: bool = False):
        self.shape = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                      else {"data": 16, "model": 16})


def roofline_cell(arch: str, shape: str, mesh=None, schedule: str = "1f1b",
                  table: ScheduleTable | None = None,
                  op_costs: dict | None = None) -> CellRoofline:
    from repro.pipeline import schedules
    from repro.core.taskgraph import PipelineSpec

    mesh = mesh or ProductionMeshShape()
    plan = plan_cell(arch, shape, mesh)
    model = plan.model
    S = model.num_stages
    M = plan.num_microbatches
    if plan.step == "train" and table is None:
        spec = PipelineSpec(S, M)
        table = schedules.BUILDERS[schedule](spec)
    oc = op_costs or per_op_costs(plan)

    if plan.step == "train":
        # per-stage totals (first / mid / last archetypes)
        totals = {}
        for name, extra_f, extra_b in (
            ("first", oc["embed"], {"flops": oc["embed"]["flops"] * 2,
                                    "bytes": oc["embed"]["bytes"] * 2}),
            ("mid", {"flops": 0.0, "bytes": 0.0}, {"flops": 0.0, "bytes": 0.0}),
            ("last", oc["ce"], None),
        ):
            f = {k: oc["F"][k] + extra_f[k] for k in ("flops", "bytes")}
            if name == "last":
                b = oc["B_last"]
            else:
                b = {k: oc["B"][k] + extra_b[k] for k in ("flops", "bytes")}
            totals[name] = {k: M * (f[k] + b[k]) for k in ("flops", "bytes")}
        worst = max(totals.values(), key=lambda t: t["flops"])
        hlo_flops = worst["flops"]
        hlo_bytes = worst["bytes"]
        # static tick timing: slowest stage per tick
        op_time = {}
        for name in ("first", "mid", "last"):
            tf = totals[name]["flops"] / M / 2  # per (F+B)/2 approx split
        f_t = {
            "first": _t(oc["F"], oc["embed"]),
            "mid": _t(oc["F"]),
            "last": _t(oc["F"], oc["ce"]),
        }
        b_t = {
            "first": _t(oc["B"], oc["embed"], oc["embed"]),
            "mid": _t(oc["B"]),
            "last": _t(oc["B_last"]),
        }
        arch_of = lambda s: ("first" if s == 0 else
                             "last" if s == S - 1 else "mid")
        permute_t = 2 * plan.mb_rows * (plan.seq_len + plan.enc_len) \
            * model.cfg.d_model * 2 / LINK_BW
        est = 0.0
        for t in range(table.num_ticks):
            tick_max = permute_t
            for s in range(S):
                op = int(table.ops[s, t])
                if op == OP_F:
                    tick_max = max(tick_max, f_t[arch_of(s)])
                elif op == OP_B:
                    tick_max = max(tick_max, b_t[arch_of(s)])
            est += tick_max
        colls = collective_bytes(plan, table)
        est += (colls["grad_rs"] + colls["param_ag"] + colls["io_ar"]) / LINK_BW
        coll_s = colls["total"] / LINK_BW
    else:
        table_t = plan.num_microbatches + S - 1
        hlo_flops = M * oc["F_dec"]["flops"]
        hlo_bytes = M * oc["F_dec"]["bytes"]
        colls = collective_bytes(plan, None)
        coll_s = colls["total"] / LINK_BW
        est = table_t * max(_t(oc["F_dec"]),
                            plan.mb_rows * model.cfg.d_model * 2 / LINK_BW)

    mf = model.model_flops(plan.cell)
    compute_s = hlo_flops / PEAK_FLOPS
    memory_s = hlo_bytes / HBM_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    useful = mf["model_flops"] / CHIPS / max(hlo_flops, 1.0)
    mfu = mf["model_flops"] / (CHIPS * PEAK_FLOPS * max(est, 1e-12))
    return CellRoofline(
        arch=arch, shape=shape, schedule=schedule,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant, model_flops=mf["model_flops"],
        hlo_flops_device=hlo_flops, useful_ratio=useful,
        est_step_s=est, projected_mfu=mfu,
    )


def _t(*costs) -> float:
    f = sum(c["flops"] for c in costs)
    b = sum(c["bytes"] for c in costs)
    return max(f / PEAK_FLOPS, b / HBM_BW)
