"""xLSTM-350M — sLSTM + mLSTM blocks, xLSTM[7:1] layer ratio.
[arXiv:2405.04517; unverified]  Runs long_500k (recurrent state)."""
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                # xLSTM blocks carry their own projections
    vocab_size=50304,
    layer_pattern=tuple(
        "slstm" if (i + 1) % 8 == 0 else "mlstm" for i in range(24)
    ),
    dtype=jnp.bfloat16,
    sub_quadratic=True,
)
