"""One-shot ``explain(trace)`` health report: name the bottleneck, rank
the fixes.

Pulls the observability stack together over a single recorded trace:

* **critical path** (``obs.critpath``) — which category of work bounded
  the makespan, decomposed to 100%;
* **what-if ranking** (``obs.whatif``) — predicted makespan gain of
  speeding up each op class, each stage, and the comm latency class, best
  first (Coz-style: predicted *without* re-running anything);
* **straggler flags** (``obs.cost_table``) — stages whose per-op duration
  EWMAs sit well above the fleet median (the same signal the adaptive
  loop's drift detector consumes);
* **bubble cross-check** (``obs.bubbles``) — the dominant *idle* class
  must be consistent with the critical path's binding category; given a
  baseline trace, checks that the class ``bubbles.compare`` says was
  removed is the one the critical path shifted off of.

CLI::

    PYTHONPATH=src python -m repro.obs.report TRACE.jsonl \\
        [--baseline BASE.jsonl] [--factor 0.75] [--json] \\
        [--perfetto OUT.perfetto.json]

``launch.train --explain`` and ``benchmarks.run --explain`` print the same
report for their recorded runs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro.core.taskgraph import Kind, PipelineSpec

from repro.obs import bubbles as _bub
from repro.obs import whatif as _wi
from repro.obs.cost_table import OnlineCostTable
from repro.obs.critpath import CP_CATEGORIES, CritPathReport, ExecGraph
from repro.runtime.rrfp import trace as _tr

#: critical-path category -> bubble classes it plausibly shows up as in
#: the per-stage idle decomposition (the cross-check's consistency map)
CP_TO_BUBBLE = {
    "compute": ("dependency_wait", "warmup", "drain"),
    "comm": ("starvation", "dependency_wait"),
    "gate": ("tp_gate", "starvation"),
    "dispatch": ("backpressure", "starvation"),
    "recovery": ("recovery",),
}

#: flag a stage when its per-op EWMA exceeds this multiple of the
#: cross-stage median for that op
STRAGGLER_RATIO = 1.5


@dataclasses.dataclass
class ExplainReport:
    """The assembled health report (see :func:`explain`)."""

    makespan: float
    meta: dict
    critpath: CritPathReport
    bottleneck: str              # human phrasing of the binding category
    ranking: list[dict]          # what-if gains, best first
    stragglers: list[dict]
    bubble_dominant: str         # dominant idle class across stages
    crosscheck: dict             # consistency of bubbles vs critical path
    whatif_factor: float

    def to_json(self) -> dict:
        return {
            "makespan": self.makespan,
            "meta": {k: self.meta.get(k) for k in
                     ("num_stages", "num_microbatches", "mode", "hint",
                      "split_backward", "substrate", "recoveries")},
            "critical_path": self.critpath.to_json(),
            "bottleneck": self.bottleneck,
            "whatif": {"factor": self.whatif_factor,
                       "ranking": self.ranking},
            "stragglers": self.stragglers,
            "bubble_dominant": self.bubble_dominant,
            "crosscheck": self.crosscheck,
        }

    def format(self, top: int = 5) -> str:
        m = self.meta
        lines = ["== makespan explained " + "=" * 42]
        lines.append(
            f"makespan {self.makespan:.6f}s — {m.get('num_stages', '?')} "
            f"stages x {m.get('num_microbatches', '?')} microbatches, "
            f"mode={m.get('mode', '?')}"
            + (f", hint={m.get('hint')}" if m.get("hint") else "")
            + (f", {self.critpath.recovery_windows} recovery window(s)"
               if self.critpath.recovery_windows else ""))
        lines.append(f"critical path: {self.critpath.path_nodes} nodes; "
                     f"binding bottleneck: {self.bottleneck}")
        lines.append(self.critpath.table())
        lines.append(f"-- what-if (virtual speedups, "
                     f"factor {self.whatif_factor:g}) " + "-" * 20)
        for r in self.ranking[:top]:
            lines.append(
                f"  {r['speedup']:<24} -> {r['predicted_makespan']:.6f}s "
                f"({-r['gain_frac']:+.1%})")
        if self.stragglers:
            lines.append("-- stragglers (per-op EWMA vs stage median) " +
                         "-" * 14)
            for s in self.stragglers:
                lines.append(
                    f"  stage {s['stage']} {s['op']}: {s['ewma']:.6f}s = "
                    f"{s['ratio']:.2f}x median ({s['median']:.6f}s)")
        else:
            lines.append("stragglers: none flagged "
                         f"(>{STRAGGLER_RATIO:g}x median)")
        cc = self.crosscheck
        verdict = ("consistent" if cc.get("consistent")
                   else "INCONSISTENT — inspect both reports")
        if cc.get("baseline"):
            lines.append(
                f"bubble cross-check vs baseline: compare() removed "
                f"'{cc['top_removed_bubble']}', critical path shifted off "
                f"'{cc['top_shifted_category']}' ({verdict})")
        else:
            lines.append(
                f"bubble cross-check: dominant idle class "
                f"'{self.bubble_dominant}' vs critical-path "
                f"'{self.critpath.top_category()}' ({verdict})")
        return "\n".join(lines)


def _stragglers(trace: _tr.Trace, spec: PipelineSpec) -> list[dict]:
    table = OnlineCostTable(spec.num_stages)
    table.update_from_trace(trace)
    kinds = [Kind.F, Kind.B] + ([Kind.W] if spec.split_backward else [])
    if spec.split_backward:
        labels = {Kind.F: "F", Kind.B: "dX", Kind.W: "dW"}
    else:
        labels = {Kind.F: "F", Kind.B: "B", Kind.W: "W"}
    out: list[dict] = []
    for kind in kinds:
        vals = {s: table.value(s, kind) for s in range(spec.num_stages)
                if table.samples(s, kind) > 0}
        if len(vals) < 2:
            continue
        ordered = sorted(vals.values())
        mid = len(ordered) // 2
        med = (ordered[mid] if len(ordered) % 2
               else 0.5 * (ordered[mid - 1] + ordered[mid]))
        if med <= 0:
            continue
        for s, v in sorted(vals.items()):
            if v > STRAGGLER_RATIO * med:
                out.append({
                    "stage": s, "op": labels[kind],
                    "ewma": v, "median": med, "ratio": v / med,
                })
    return out


def _bottleneck_phrase(rep: CritPathReport) -> str:
    top = rep.top_category()
    frac = rep.fractions()[top]
    if top == "compute" and rep.compute_by_stage:
        s = max(rep.compute_by_stage, key=lambda k: rep.compute_by_stage[k])
        ops = sorted(rep.compute_by_op,
                     key=lambda o: -rep.compute_by_op[o])
        return (f"compute ({frac:.0%} of makespan), heaviest on stage {s}"
                + (f" ({ops[0]})" if ops else ""))
    phrases = {
        "comm": "message latency (SEND->DELIVER hops)",
        "gate": "gate admission (TP all-ranks / fan-in skew / coordination)",
        "dispatch": "dispatch waits (backpressure / W-cap / arbitration)",
        "recovery": "fault recovery (MTTR inside FAIL..RECOVERY_END)",
    }
    return f"{phrases.get(top, top)} ({frac:.0%} of makespan)"


def explain(trace: _tr.Trace, spec: PipelineSpec | None = None, *,
            factor: float = 0.75,
            baseline: _tr.Trace | None = None) -> ExplainReport:
    """Assemble the one-shot health report for a recorded trace."""
    if spec is None:
        spec = _bub.spec_from_meta(trace.meta)
    graph = ExecGraph.build(trace, spec)
    rep = graph.decompose()
    ranking = _wi.rank(graph, factor=factor)
    bub = _bub.decompose(trace, spec)
    totals = bub.category_totals()
    bubble_dominant = max(totals, key=lambda c: totals[c])
    if baseline is not None:
        base_graph = ExecGraph.build(baseline)
        base_rep = base_graph.decompose()
        cmp = _bub.compare(_bub.decompose(baseline), bub)
        shift = {c: base_rep.categories[c] - rep.categories[c]
                 for c in CP_CATEGORIES}
        top_shift = max(shift, key=lambda c: shift[c])
        crosscheck = {
            "baseline": True,
            "top_removed_bubble": cmp["top_removed_category"],
            "top_shifted_category": top_shift,
            "speedup": cmp["speedup"],
            "consistent": cmp["top_removed_category"]
                          in CP_TO_BUBBLE.get(top_shift, ()),
        }
    else:
        crosscheck = {
            "baseline": False,
            "dominant_bubble": bubble_dominant,
            "cp_top": rep.top_category(),
            "consistent": bubble_dominant
                          in CP_TO_BUBBLE.get(rep.top_category(), ()),
        }
    return ExplainReport(
        makespan=graph.makespan, meta=dict(trace.meta), critpath=rep,
        bottleneck=_bottleneck_phrase(rep), ranking=ranking,
        stragglers=_stragglers(trace, spec),
        bubble_dominant=bubble_dominant, crosscheck=crosscheck,
        whatif_factor=factor)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Explain a recorded trace: critical path, what-if "
                    "ranking, stragglers, bubble cross-check.")
    ap.add_argument("trace", help="recorded trace (.jsonl, Trace.save)")
    ap.add_argument("--baseline", default=None,
                    help="baseline trace for the removed-bubble cross-check")
    ap.add_argument("--factor", type=float, default=0.75,
                    help="virtual speedup factor for the what-if ranking "
                         "(default 0.75)")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of text")
    ap.add_argument("--perfetto", default=None, metavar="PATH",
                    help="also export a Perfetto timeline with the "
                         "critical path highlighted and slices shaded by "
                         "slack")
    args = ap.parse_args(argv)
    trace = _tr.Trace.load(args.trace)
    baseline = _tr.Trace.load(args.baseline) if args.baseline else None
    rep = explain(trace, factor=args.factor, baseline=baseline)
    if args.json:
        json.dump(rep.to_json(), sys.stdout, indent=2)
        print()
    else:
        print(rep.format())
    if args.perfetto:
        from repro.obs.export import export_perfetto

        export_perfetto(trace, args.perfetto, critical_path=True)
        print(f"highlighted perfetto timeline -> {args.perfetto} "
              f"(open at ui.perfetto.dev)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
