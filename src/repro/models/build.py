"""Arch registry: ArchConfig -> ArchModel, the uniform interface the pipeline
executor, dry-run and smoke tests consume.

Every architecture exposes the same contract:

* stacked per-stage layer parameters with a *union* structure across the
  arch's layer types (lax.switch selects the branch per slot; uneven
  layers-per-stage handled with enabled flags — DESIGN §3),
* ``stage_forward(stage_params, io, x, aux, rows)`` — the pipelined F body,
* ``stage_decode`` — the serve-path body with stacked per-layer caches,
* io params (embedding / head / final norm / shared blocks) that live
  outside the stage stacking,
* a single-device ``reference_forward`` used by tests,
* analytic FLOP/param accounting for the roofline.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import (
    ArchConfig,
    ShapeCell,
    dense_init,
    global_layer_index,
    keygen,
    stage_layout,
)
from repro.models.layers import (
    attention_block,
    decode_attention_block,
    decoder_layer,
    decoder_layer_decode,
    ffn_block,
    init_attention,
    init_decoder_layer,
    init_ffn,
    rmsnorm,
)


def _tree_stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


@dataclasses.dataclass
class ArchModel:
    cfg: ArchConfig
    num_stages: int
    counts: np.ndarray  # [S] true layers per stage
    l_max: int
    type_ids: np.ndarray  # [S, l_max] index into layer_types, -1 disabled
    shared_flags: np.ndarray  # [S, l_max] apply-shared-block-before-slot
    layer_types: tuple[str, ...]
    moe_layout: str = "none"  # none | ep | tp (over the data axis)

    # ------------------------------------------------------------------
    @property
    def d_model(self) -> int:
        return self.cfg.d_model

    def rows(self, stage: int) -> dict[str, np.ndarray]:
        return {
            "type_id": np.maximum(self.type_ids[stage], 0),
            "enabled": (self.type_ids[stage] >= 0).astype(np.int32),
            "shared": self.shared_flags[stage].astype(np.int32),
        }

    def all_rows(self) -> dict[str, np.ndarray]:
        return {
            "type_id": np.maximum(self.type_ids, 0),
            "enabled": (self.type_ids >= 0).astype(np.int32),
            "shared": self.shared_flags.astype(np.int32),
        }

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def init_layer_params(self, key) -> dict:
        """Union parameter struct covering every layer type of this arch."""
        cfg = self.cfg
        keys = keygen(key)
        p: dict[str, Any] = {}
        types = set(self.layer_types)
        if types & {"attn", "attn_local", "attn_global", "enc", "dec"}:
            p["blk"] = init_decoder_layer(keys, cfg)
        if "dec" in types:
            p["cross_ln"] = jnp.zeros((cfg.d_model,), cfg.dtype)
            p["cross"] = init_attention(keys, cfg, cross=True)
        if types & {"moe", "dense"}:
            p["ln1"] = jnp.zeros((cfg.d_model,), cfg.dtype)
            p["attn"] = init_attention(keys, cfg)
            p["ln2"] = jnp.zeros((cfg.d_model,), cfg.dtype)
            if "moe" in types:
                p["moe"] = moe_lib.init_moe_ffn(keys, cfg)
            if "dense" in types:
                p["dense_ffn"] = init_ffn(keys, cfg, cfg.moe.dense_d_ff)
        if "mamba" in types:
            p["mamba"] = ssm_lib.init_mamba_layer(keys, cfg)
        if "mlstm" in types:
            p["mlstm"] = xlstm_lib.init_mlstm_layer(keys, cfg)
        if "slstm" in types:
            p["slstm"] = xlstm_lib.init_slstm_layer(keys, cfg)
        return p

    def init_stage_params(self, key):
        """[S, l_max, ...] stacked union params."""
        slots = []
        for s in range(self.num_stages):
            row = [
                self.init_layer_params(jax.random.fold_in(key, s * 1000 + i))
                for i in range(self.l_max)
            ]
            slots.append(_tree_stack(row))
        return _tree_stack(slots)

    def init_io_params(self, key):
        cfg = self.cfg
        keys = keygen(key)
        v = cfg.padded_vocab()
        io: dict[str, Any] = {
            "embed": dense_init(next(keys), (v, cfg.d_model), cfg.dtype, scale=0.02),
            "head": dense_init(next(keys), (v, cfg.d_model), cfg.dtype),
            "final_ln": jnp.zeros((cfg.d_model,), cfg.dtype),
        }
        if cfg.shared_attn_period:
            io["shared_blk"] = init_decoder_layer(keys, cfg)
        return io

    # ------------------------------------------------------------------
    # layer dispatch
    # ------------------------------------------------------------------
    def _branch(self, kind: str) -> Callable:
        cfg = self.cfg

        def attn_like(p, io, x, aux, window: int, causal: bool = True):
            return decoder_layer(
                p["blk"], x, aux["positions"], cfg, causal=causal, window=window,
                mrope_pos=aux.get("mrope"),
            )

        if kind == "attn":
            return lambda p, io, x, aux: attn_like(p, io, x, aux, cfg.sliding_window)
        if kind == "attn_local":
            return lambda p, io, x, aux: attn_like(p, io, x, aux, cfg.sliding_window or 1024)
        if kind == "attn_global":
            return lambda p, io, x, aux: attn_like(p, io, x, aux, 0)
        if kind == "enc":

            def enc_fn(p, io, x, aux):
                # x = concat(dec_zeros, enc); encoder transforms the enc part
                dec_len = aux["dec_len"]
                enc = x[:, dec_len:]
                pos = jnp.broadcast_to(
                    jnp.arange(enc.shape[1])[None], enc.shape[:2])
                enc = decoder_layer(p["blk"], enc, pos, cfg, causal=False)
                return jnp.concatenate([x[:, :dec_len], enc], axis=1)

            return enc_fn
        if kind == "dec":

            def dec_fn(p, io, x, aux):
                dec_len = aux["dec_len"]
                dec, enc = x[:, :dec_len], x[:, dec_len:]
                pos = jnp.broadcast_to(jnp.arange(dec_len)[None], dec.shape[:2])
                h = rmsnorm(dec, p["blk"]["ln1"], cfg.norm_eps)
                dec = dec + attention_block(p["blk"]["attn"], h, pos, cfg)
                h = rmsnorm(dec, p["cross_ln"], cfg.norm_eps)
                dec = dec + attention_block(
                    p["cross"], h, pos, cfg, causal=False, kv_src=enc, rope=False)
                h = rmsnorm(dec, p["blk"]["ln2"], cfg.norm_eps)
                dec = dec + ffn_block(p["blk"]["ffn"], h, cfg.act)
                return jnp.concatenate([dec, enc], axis=1)

            return dec_fn
        if kind in ("moe", "dense"):

            def moe_fn(p, io, x, aux, kind=kind):
                h = rmsnorm(x, p["ln1"], cfg.norm_eps)
                x = x + attention_block(p["attn"], h, aux["positions"], cfg)
                h = rmsnorm(x, p["ln2"], cfg.norm_eps)
                if kind == "dense":
                    return x + ffn_block(p["dense_ffn"], h, cfg.act)
                return x + moe_lib.moe_ffn(
                    p["moe"], h, cfg, layout=aux.get("moe_layout", "none"),
                    axis_name="data", axis_size=aux.get("data_size", 1))

            return moe_fn
        if kind == "mamba":
            return lambda p, io, x, aux: ssm_lib.mamba_layer(p["mamba"], x, cfg)
        if kind == "mlstm":
            return lambda p, io, x, aux: xlstm_lib.mlstm_layer(p["mlstm"], x, cfg)
        if kind == "slstm":
            return lambda p, io, x, aux: xlstm_lib.slstm_layer(p["slstm"], x, cfg)
        raise ValueError(kind)

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------
    def stage_forward(self, stage_params, io, x, aux, rows, remat: bool = True):
        """Apply this stage's layer slots.  stage_params leaves [l_max, ...];
        rows: dict of [l_max] int arrays (type_id / enabled / shared).

        Each slot is rematerialized under autodiff (``remat``): the stage
        VJP then stores one activation per layer instead of every layer's
        internals — the memory term that makes 32k-seq stages fit HBM.
        """
        cfg = self.cfg
        branches = [self._branch(k) for k in self.layer_types]

        def slot_compute(p_slot, io, x, tid, en, sh):
            if cfg.shared_attn_period:
                x = jax.lax.cond(
                    (sh > 0) & (en > 0),
                    lambda x: decoder_layer(io["shared_blk"], x, aux["positions"], cfg),
                    lambda x: x,
                    x,
                )
            if len(branches) == 1:
                y = branches[0](p_slot, io, x, aux)
            else:
                y = jax.lax.switch(
                    tid, [lambda p, x, b=b: b(p, io, x, aux) for b in branches],
                    p_slot, x)
            return jnp.where(en > 0, y, x)

        # Static specialization: when rows are concrete (per-op roofline
        # costing, reference forward), branch in Python so HloCostAnalysis
        # doesn't count untaken cond/switch branches (a real TPU skips them
        # at runtime; the SPMD executor passes traced rows and keeps the
        # dynamic path).
        static = isinstance(rows["type_id"], np.ndarray)
        if static:

            def slot_static(p_slot, io, x, tid, en, sh):
                if not en:
                    return x
                if cfg.shared_attn_period and sh:
                    x = decoder_layer(io["shared_blk"], x, aux["positions"], cfg)
                return branches[tid](p_slot, io, x, aux)

            policy = (jax.checkpoint_policies.save_only_these_names(
                "moe_dispatched") if cfg.family == "moe" else None)
            body = jax.checkpoint(slot_static, static_argnums=(3, 4, 5),
                                  policy=policy) if remat else slot_static
        elif remat:
            policy = (jax.checkpoint_policies.save_only_these_names(
                "moe_dispatched") if cfg.family == "moe" else None)
            slot_compute = jax.checkpoint(slot_compute, policy=policy)

        # NOTE: the slot loop is python-unrolled (l_max <= ~6), NOT lax.scan:
        # scan's linearization partial-eval hoists the attention kernels'
        # "known" mask blocks into per-step stacked residuals (measured 59 GB
        # at 32k seq for a length-1 scan vs 6.9 GB unrolled) — see
        # EXPERIMENTS.md §Perf iteration log.
        l_max = jax.tree.leaves(stage_params)[0].shape[0]
        if static:
            for i in range(l_max):
                p_slot = jax.tree.map(lambda p: p[i], stage_params)
                x = body(p_slot, io, x, int(rows["type_id"][i]),
                         bool(rows["enabled"][i]), bool(rows["shared"][i]))
            return x
        tid = jnp.asarray(rows["type_id"])
        en = jnp.asarray(rows["enabled"])
        sh = jnp.asarray(rows["shared"])
        for i in range(l_max):
            p_slot = jax.tree.map(lambda p: p[i], stage_params)
            x = slot_compute(p_slot, io, x, tid[i], en[i], sh[i])
        return x

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def init_layer_cache(self, batch: int, seq: int, enc_len: int = 0) -> dict:
        """Union cache struct for one layer slot."""
        cfg = self.cfg
        c: dict[str, Any] = {}
        types = set(self.layer_types)
        kv = cfg.num_kv_heads
        hd = cfg.resolved_head_dim
        if types & {"attn", "attn_local", "attn_global", "dec", "moe", "dense"} or cfg.shared_attn_period:
            c["k"] = jnp.zeros((batch, seq, kv, hd), cfg.dtype)
            c["v"] = jnp.zeros((batch, seq, kv, hd), cfg.dtype)
        if "dec" in types:
            c["xk"] = jnp.zeros((batch, enc_len, kv, hd), cfg.dtype)
            c["xv"] = jnp.zeros((batch, enc_len, kv, hd), cfg.dtype)
        if "mamba" in types:
            c["mamba"] = ssm_lib.init_mamba_cache(batch, cfg)
        if "mlstm" in types:
            c["mlstm"] = xlstm_lib.init_mlstm_cache(batch, cfg)
        if "slstm" in types:
            c["slstm"] = xlstm_lib.init_slstm_cache(batch, cfg)
        return c

    def init_stage_cache(self, batch: int, seq: int, enc_len: int = 0):
        one = self.init_layer_cache(batch, seq, enc_len)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None, None], (self.num_stages, self.l_max) + x.shape
            ),
            one,
        )

    def _decode_branch(self, kind: str) -> Callable:
        cfg = self.cfg

        def attn_like(p, io, x, cache, pos, aux, window):
            kvc = {"k": cache["k"], "v": cache["v"]}
            y, kvc = decoder_layer_decode(
                p["blk"], x, kvc, pos, cfg, window=window,
                axis_name=aux.get("sp_axis"))
            return y, {**cache, **kvc}

        if kind == "attn":
            return lambda p, io, x, c, pos, aux: attn_like(
                p, io, x, c, pos, aux, cfg.sliding_window)
        if kind == "attn_local":
            return lambda p, io, x, c, pos, aux: attn_like(
                p, io, x, c, pos, aux, cfg.sliding_window or 1024)
        if kind == "attn_global":
            return lambda p, io, x, c, pos, aux: attn_like(p, io, x, c, pos, aux, 0)
        if kind == "dec":

            def dec_fn(p, io, x, cache, pos, aux):
                kvc = {"k": cache["k"], "v": cache["v"]}
                h = rmsnorm(x, p["blk"]["ln1"], cfg.norm_eps)
                a, kvc = decode_attention_block(p["blk"]["attn"], h, kvc, pos, cfg)
                x = x + a
                # cross attention against the pre-filled encoder KV cache
                h = rmsnorm(x, p["cross_ln"], cfg.norm_eps)
                b = x.shape[0]
                q, _, _ = (
                    h @ p["cross"]["wq"],
                    None,
                    None,
                )
                q = q.reshape(b, 1, cfg.num_heads, cfg.resolved_head_dim)
                enc_len = cache["xk"].shape[1]
                o = ops.decode_attention(q, cache["xk"], cache["xv"], enc_len)
                x = x + o.reshape(b, 1, -1) @ p["cross"]["wo"]
                h = rmsnorm(x, p["blk"]["ln2"], cfg.norm_eps)
                x = x + ffn_block(p["blk"]["ffn"], h, cfg.act)
                return x, {**cache, **kvc}

            return dec_fn
        if kind == "enc":
            # encoder layers are inert at decode time (context pre-filled)
            return lambda p, io, x, c, pos, aux: (x, c)
        if kind in ("moe", "dense"):

            def moe_fn(p, io, x, cache, pos, aux, kind=kind):
                kvc = {"k": cache["k"], "v": cache["v"]}
                h = rmsnorm(x, p["ln1"], cfg.norm_eps)
                a, kvc = decode_attention_block(p["attn"], h, kvc, pos, cfg)
                x = x + a
                h = rmsnorm(x, p["ln2"], cfg.norm_eps)
                if kind == "dense":
                    y = ffn_block(p["dense_ffn"], h, cfg.act)
                else:
                    y = moe_lib.moe_ffn(
                        p["moe"], h, cfg, layout=aux.get("moe_layout", "none"),
                        axis_name="data", axis_size=aux.get("data_size", 1))
                return x + y, {**cache, **kvc}

            return moe_fn
        if kind == "mamba":

            def mamba_fn(p, io, x, cache, pos, aux):
                y, mc = ssm_lib.mamba_layer_decode(p["mamba"], x, cache["mamba"], cfg)
                return y, {**cache, "mamba": mc}

            return mamba_fn
        if kind == "mlstm":

            def mlstm_fn(p, io, x, cache, pos, aux):
                y, mc = xlstm_lib.mlstm_layer_decode(p["mlstm"], x, cache["mlstm"], cfg)
                return y, {**cache, "mlstm": mc}

            return mlstm_fn
        if kind == "slstm":

            def slstm_fn(p, io, x, cache, pos, aux):
                y, sc = xlstm_lib.slstm_layer_decode(p["slstm"], x, cache["slstm"], cfg)
                return y, {**cache, "slstm": sc}

            return slstm_fn
        raise ValueError(kind)

    def stage_decode(self, stage_params, io, x, stage_cache, pos, aux, rows):
        """x: [b, 1, d]; stage_cache leaves [l_max, ...]."""
        cfg = self.cfg
        branches = [self._decode_branch(k) for k in self.layer_types]

        def slot(x, scan_in):
            p_slot, cache_slot, tid, en, sh = scan_in
            if cfg.shared_attn_period:
                # the shared block's KV cache rides in the slot's k/v fields
                def shared_apply(x, kvc):
                    return decoder_layer_decode(io["shared_blk"], x, kvc, pos, cfg)

                kvc = {"k": cache_slot["k"], "v": cache_slot["v"]}
                x, kvc = jax.lax.cond(
                    (sh > 0) & (en > 0), shared_apply,
                    lambda x, kvc: (x, kvc), x, kvc)
                cache_slot = {**cache_slot, **kvc}
            if len(branches) == 1:
                y, c = branches[0](p_slot, io, x, cache_slot, pos, aux)
            else:
                y, c = jax.lax.switch(
                    tid,
                    [lambda p, x, cc, b=b: b(p, io, x, cc, pos, aux) for b in branches],
                    p_slot, x, cache_slot)
            y = jnp.where(en > 0, y, x)
            c = jax.tree.map(lambda new, old: jnp.where(en > 0, new, old), c, cache_slot)
            return y, c

        # python-unrolled like stage_forward (uniform memory behaviour)
        l_max = jax.tree.leaves(stage_params)[0].shape[0]
        tid = jnp.asarray(rows["type_id"])
        en_r = jnp.asarray(rows["enabled"])
        sh = jnp.asarray(rows["shared"])
        new_slots = []
        for i in range(l_max):
            p_slot = jax.tree.map(lambda p: p[i], stage_params)
            c_slot = jax.tree.map(lambda c: c[i], stage_cache)
            x, c_new = slot(x, (p_slot, c_slot, tid[i], en_r[i], sh[i]))
            new_slots.append(c_new)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_slots)
        return x, new_cache

    # ------------------------------------------------------------------
    # embedding / head (pure versions; the executor adds vocab parallelism)
    # ------------------------------------------------------------------
    def embed(self, io, batch: dict):
        if self.cfg.embed_input:
            return batch["embeds"].astype(self.cfg.dtype)
        return io["embed"][batch["tokens"]]

    def head_logits(self, io, x):
        h = rmsnorm(x, io["final_ln"], self.cfg.norm_eps)
        return h @ io["head"].T

    # ------------------------------------------------------------------
    # reference single-device forward (tests)
    # ------------------------------------------------------------------
    def reference_forward(self, stage_params, io, batch: dict, aux: dict):
        x = self.embed(io, batch)
        for s in range(self.num_stages):
            sp = jax.tree.map(lambda p: p[s], stage_params)
            x = self.stage_forward(sp, io, x, aux, self.rows(s))
        return self.head_logits(io, x)

    # ------------------------------------------------------------------
    # analytic accounting
    # ------------------------------------------------------------------
    def model_flops(self, cell: ShapeCell) -> dict[str, float]:
        """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE), N excl. embed."""
        cfg = self.cfg
        tokens = cell.seq_len * cell.global_batch if cell.step == "train" else cell.global_batch
        n_active = cfg.active_param_count() + cfg.padded_vocab() * cfg.d_model
        n_total = cfg.param_count(include_embed=False) + cfg.padded_vocab() * cfg.d_model
        mult = 6 if cell.step == "train" else 2
        # attention context FLOPs (not in 6ND): 12*s*ctx*d_attn per layer
        attn_layers = sum(
            1 for k in cfg.pattern
            if k in ("attn", "attn_global", "moe", "dense", "dec", "enc")
        ) + (len([1 for f in self.shared_flags.ravel() if f]) if cfg.shared_attn_period else 0)
        local_layers = sum(1 for k in cfg.pattern if k == "attn_local")
        hq, hd = cfg.num_heads, cfg.resolved_head_dim
        if cell.step == "train":
            ctx = cell.seq_len / 2
            attn_flops = mult * cell.global_batch * cell.seq_len * (
                attn_layers * ctx + local_layers * min(cfg.sliding_window or 1024, ctx)
            ) * 2 * hq * hd
        else:
            ctx = cell.seq_len
            attn_flops = mult * cell.global_batch * (
                attn_layers * ctx + local_layers * min(cfg.sliding_window or 1024, ctx)
            ) * 2 * hq * hd
        return {
            "model_flops": mult * n_active * tokens + attn_flops,
            "model_flops_total_params": mult * n_total * tokens + attn_flops,
            "tokens": tokens,
            "n_active": n_active,
            "n_total": n_total,
        }


# ---------------------------------------------------------------------------
# builder
# ---------------------------------------------------------------------------
def build(cfg: ArchConfig, num_stages: int = 16) -> ArchModel:
    counts, l_max = stage_layout(cfg.num_layers, num_stages)
    gli = global_layer_index(counts)  # [S, l_max], -1 disabled
    pattern = cfg.pattern
    types = cfg.layer_types()
    type_ids = np.full((num_stages, l_max), -1, dtype=np.int64)
    shared = np.zeros((num_stages, l_max), dtype=np.int64)
    for s in range(num_stages):
        for i in range(l_max):
            g = gli[s, i]
            if g >= 0:
                type_ids[s, i] = types.index(pattern[g])
                if cfg.shared_attn_period and g % cfg.shared_attn_period == 0:
                    shared[s, i] = 1
    layout = "none"
    if cfg.family == "moe":
        assert cfg.moe is not None
        layout = "ep" if cfg.moe.num_experts >= 16 else "tp"
    return ArchModel(
        cfg=cfg,
        num_stages=num_stages,
        counts=counts,
        l_max=l_max,
        type_ids=type_ids,
        shared_flags=shared,
        layer_types=types,
        moe_layout=layout,
    )
