"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, positions, causal: bool = True, window: int = 0):
    """Dense-softmax reference attention.

    q: [b, sq, hq, hd]; k, v: [b, sk, hkv, hd]; positions: [b, sq] absolute
    query positions (key positions are arange(sk)).
    """
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    qf = (q.astype(jnp.float32) * hd**-0.5).reshape(b, sq, hkv, g, hd)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qf, k.astype(jnp.float32))
    kpos = jnp.arange(sk)
    mask = jnp.ones((b, sq, sk), jnp.bool_)
    if causal:
        mask &= positions[:, :, None] >= kpos[None, None, :]
    if window > 0:
        mask &= positions[:, :, None] - kpos[None, None, :] < window
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqj,bjkd->bkgqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(b, sq, hq, hd).astype(q.dtype)


def decode_ref(q, k_cache, v_cache, lengths, window: int = 0):
    """Single-token decode attention reference.

    q: [b, 1, hq, hd]; caches: [b, S, hkv, hd]; lengths: [b].
    """
    b, _, hq, hd = q.shape
    _, S, hkv, _ = k_cache.shape
    g = hq // hkv
    qf = (q.astype(jnp.float32) * hd**-0.5).reshape(b, hkv, g, hd)
    s = jnp.einsum("bkgd,bjkd->bkgj", qf, k_cache.astype(jnp.float32))
    pos = jnp.arange(S)[None, :]
    mask = pos < lengths[:, None]
    if window > 0:
        mask &= pos >= lengths[:, None] - window
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgj,bjkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, hq, hd).astype(q.dtype)


def ssd_ref(x, dt, A, B, C, D, chunk: int = 0):
    """Sequential (exact) Mamba-2 SSD recurrence.

    x: [b, s, nh, hd]; dt: [b, s, nh]; A: [nh] (negative); B, C: [b, s, ds];
    D: [nh].  Returns y: [b, s, nh, hd].
    State: h[nh, hd, ds];  h_t = exp(A*dt) h_{t-1} + dt * x_t B_t^T;
    y_t = (h_t C_t) + D * x_t.
    """
    bsz, s, nh, hd = x.shape
    ds = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp  # [b,nh,hd], [b,nh], [b,ds], [b,ds]
        decay = jnp.exp(Af[None, :] * dt_t)  # [b, nh]
        upd = jnp.einsum("bnh,bs->bnhs", x_t * dt_t[..., None], b_t)
        h = h * decay[..., None, None] + upd
        y_t = jnp.einsum("bnhs,bs->bnh", h, c_t)
        return h, y_t

    h0 = jnp.zeros((bsz, nh, hd, ds), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype)


def ssd_ref_with_state(x, dt, A, B, C, D):
    """Like ``ssd_ref`` but also returns the final state (decode handoff)."""
    bsz, s, nh, hd = x.shape
    ds = B.shape[-1]
    y = ssd_ref(x, dt, A, B, C, D)
    # recompute final state
    def step(h, inp):
        x_t, dt_t, b_t = inp
        decay = jnp.exp(A.astype(jnp.float32)[None, :] * dt_t)
        upd = jnp.einsum("bnh,bs->bnhs", x_t * dt_t[..., None], b_t)
        return h * decay[..., None, None] + upd, None
    h0 = jnp.zeros((bsz, nh, hd, ds), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(B.astype(jnp.float32), 1, 0))
    h, _ = jax.lax.scan(step, h0, xs)
    return y, h


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * (1.0 + scale.astype(jnp.float32))).astype(dt)
