"""Granite-34B (code) — dense llama-arch, 88L, GQA kv=1 (MQA).
[arXiv:2405.04324; hf]"""
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,       # MQA
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    rope_theta=10_000.0,
    act="gelu",            # gpt_bigcode-style 2-matrix FFN (-> ~34B total)
    dtype=jnp.bfloat16,
)
