"""Adaptive scheduling (repro.runtime.adaptive) + drifting-cost chaos.

Pins the PR-level acceptance invariants:

* the scheduler's initial table is exactly what offline synthesis ships;
* the drift detector's gates — cold-table sample floor, resynth cadence,
  improvement threshold, hysteresis streak (including reset on a
  non-improving check) — each fire deterministically;
* a stationary closed loop never swaps (no flapping), a drifting one swaps
  and its post-swap makespan beats the decayed static table;
* ``drift_scale`` is the documented pure function of (profile, stage, step)
  and composes multiplicatively with static stragglers;
* ``price_orders`` prices a table at the makespan the actor runtime
  realizes for it on the same expected costs;
* ``synthesize`` prices split-backward specs against the ZB baseline
  (1F1B is undefined once the backward is split).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import CostModel, HintKind, Kind, PipelineSpec
from repro.core.costs import JitterModel
from repro.core.synthesis import price_orders, synthesize
from repro.obs import MetricsRegistry
from repro.runtime.adaptive import AdaptiveConfig, AdaptiveScheduler
from repro.runtime.rrfp import ActorConfig, ActorDriver
from repro.runtime.rrfp.chaos import ChaosConfig, drift_chaos, parse_chaos


def _split_workload(S=4, M=8, comm=0.3, base=None):
    spec = PipelineSpec(S, M, split_backward=True)
    base = np.asarray(base if base is not None
                      else np.linspace(1.0, 1.3, S))
    costs = CostModel(
        f_cost=base, b_cost=base, w_cost=base, comm_base=comm,
        compute_jitter=JitterModel(), comm_jitter=JitterModel())
    return spec, costs


# heterogeneous per-stage costs where a 2x drift on stage 4 changes the
# best table (the benchmark's pp6_step cell)
_B6 = (1.0, 1.2, 0.9, 1.3, 0.8, 1.1)


def _seed_registry(reg, spec, costs, scale=None):
    """Seed every (stage, kind) EWMA as if ``min_samples`` completions at
    the scaled base cost had been observed — a deterministic stand-in for
    a measured run."""
    scale = scale or {}
    kinds = [Kind.F, Kind.B] + ([Kind.W] if spec.split_backward else [])
    per_kind = {Kind.F: costs.f_cost, Kind.B: costs.b_cost,
                Kind.W: costs.w_cost}
    for s in range(spec.num_stages):
        for k in kinds:
            reg.shard(s).cost_ewma[k].seed(
                float(per_kind[k][s]) * scale.get(s, 1.0), 4)
    return reg


class TestAdaptiveScheduler:
    def test_initial_table_matches_offline_synthesis(self):
        spec, costs = _split_workload()
        sched = AdaptiveScheduler(
            spec, costs, AdaptiveConfig(hint=HintKind.BFW))
        syn = synthesize(spec, costs, hint=HintKind.BFW)
        assert sched.table == syn.stage_orders
        assert sched.version == 0 and sched.swaps == []

    def test_cold_table_skips_check(self):
        spec, costs = _split_workload()
        sched = AdaptiveScheduler(
            spec, costs, AdaptiveConfig(hint=HintKind.BFW, min_samples=4))
        d = sched.maybe_resynthesize(0)
        assert not d.checked and not d.swapped
        assert "cold" in d.reason
        assert sched.version == 0

    def test_partial_samples_still_cold(self):
        # one warm stage is not enough: *every* stage needs min_samples
        spec, costs = _split_workload()
        sched = AdaptiveScheduler(
            spec, costs, AdaptiveConfig(hint=HintKind.BFW, min_samples=4))
        for k in (Kind.F, Kind.B, Kind.W):
            sched.registry.shard(0).cost_ewma[k].seed(1.0, 4)
        assert not sched.maybe_resynthesize(0).checked

    def test_off_cadence_skips_check(self):
        spec, costs = _split_workload()
        sched = AdaptiveScheduler(
            spec, costs,
            AdaptiveConfig(hint=HintKind.BFW, resynth_every=4))
        _seed_registry(sched.registry, spec, costs)
        for step in range(3):
            d = sched.maybe_resynthesize(step)
            assert not d.checked and d.reason == "off-cadence"
        assert sched.maybe_resynthesize(3).checked  # (3+1) % 4 == 0

    def test_stationary_costs_never_swap(self):
        # measured == synthesis costs: candidate re-derives the active
        # table, ratio pins to ~1.0, detector must stay quiet
        spec, costs = _split_workload()
        sched = AdaptiveScheduler(
            spec, costs, AdaptiveConfig(hint=HintKind.BFW, hysteresis=1))
        _seed_registry(sched.registry, spec, costs)
        for step in range(4):
            d = sched.maybe_resynthesize(step)
            assert d.checked and not d.swapped
            assert d.ratio == pytest.approx(1.0)
        assert sched.swaps == [] and sched.version == 0

    def test_hysteresis_streak_and_reset(self):
        # drifted -> streak 1; back to base -> reset; drifted, drifted ->
        # swap fires only on the second consecutive improving check
        spec, costs = _split_workload(S=6, M=18, comm=0.4, base=_B6)
        drift = {4: 2.0}
        sched = AdaptiveScheduler(
            spec, costs,
            AdaptiveConfig(hint=HintKind.BFW, swap_threshold=1.02,
                           hysteresis=2))

        _seed_registry(sched.registry, spec, costs, scale=drift)
        d = sched.maybe_resynthesize(0)
        assert d.checked and not d.swapped and d.streak == 1

        _seed_registry(sched.registry, spec, costs)  # drift vanishes
        d = sched.maybe_resynthesize(1)
        assert not d.swapped and d.streak == 0

        _seed_registry(sched.registry, spec, costs, scale=drift)
        assert sched.maybe_resynthesize(2).streak == 1
        d = sched.maybe_resynthesize(3)
        assert d.swapped and d.reason == "swapped"
        assert sched.version == 1 and sched.swaps == [3]
        assert d.streak == 0  # streak consumed by the swap

    def test_swap_records_predicted_category(self):
        # a fired swap annotates which critical-path category the new
        # table was predicted to shrink; non-swaps carry None
        from repro.obs.critpath import CP_CATEGORIES

        spec, costs = _split_workload(S=6, M=18, comm=0.4, base=_B6)
        sched = AdaptiveScheduler(
            spec, costs,
            AdaptiveConfig(hint=HintKind.BFW, swap_threshold=1.02,
                           hysteresis=1))
        _seed_registry(sched.registry, spec, costs, scale={4: 2.0})
        d = sched.maybe_resynthesize(0)
        assert d.swapped
        assert (d.predicted_category is None
                or d.predicted_category in CP_CATEGORIES)
        assert d.to_json()["predicted_category"] == d.predicted_category
        # the annotation never appears on a decision that did not swap
        _seed_registry(sched.registry, spec, costs)
        d2 = sched.maybe_resynthesize(1)
        assert not d2.swapped and d2.predicted_category is None

    def test_high_threshold_blocks_swap(self):
        spec, costs = _split_workload(S=6, M=18, comm=0.4, base=_B6)
        sched = AdaptiveScheduler(
            spec, costs,
            AdaptiveConfig(hint=HintKind.BFW, swap_threshold=100.0,
                           hysteresis=1))
        _seed_registry(sched.registry, spec, costs, scale={4: 2.0})
        for step in range(3):
            d = sched.maybe_resynthesize(step)
            assert d.checked and not d.swapped
            assert d.reason == "below threshold"
        assert sched.swaps == []

    def test_closed_loop_drift_swaps_and_beats_static(self):
        # the benchmark's pp6_step cell in miniature, driven end-to-end
        # through real ActorDriver runs feeding the registry
        spec = PipelineSpec(6, 12, split_backward=True)
        base = np.asarray((1.0, 1.2, 0.9, 1.3, 0.8, 1.1))
        costs = CostModel(
            f_cost=base, b_cost=base, w_cost=base, comm_base=0.4,
            compute_jitter=JitterModel(), comm_jitter=JitterModel())
        chaos0 = drift_chaos("step", {4: 2.0}, period=3)
        sched = AdaptiveScheduler(
            spec, costs,
            AdaptiveConfig(hint=HintKind.BFW, swap_threshold=1.02,
                           hysteresis=2))
        static = [list(o) for o in sched.table]
        mk_a, mk_s = [], []
        for k in range(8):
            ch = dataclasses.replace(chaos0, step=k)
            mk_a.append(ActorDriver(spec, costs, ActorConfig(
                mode="hint", hint=HintKind.BFW, hint_table=sched.table,
                hint_table_version=sched.version, chaos=ch,
                metrics=sched.registry)).run().makespan)
            sched.maybe_resynthesize(k)
            mk_s.append(ActorDriver(spec, costs, ActorConfig(
                mode="hint", hint=HintKind.BFW, hint_table=static,
                chaos=ch)).run().makespan)
        assert sched.swaps, "drift never detected"
        assert sched.version >= 1
        assert mk_a[-1] < mk_s[-1], (mk_a, mk_s)

    def test_closed_loop_stationary_never_swaps(self):
        spec, costs = _split_workload(S=4, M=8)
        sched = AdaptiveScheduler(
            spec, costs,
            AdaptiveConfig(hint=HintKind.BFW, swap_threshold=1.02,
                           hysteresis=1))
        mks = []
        for k in range(5):
            mks.append(ActorDriver(spec, costs, ActorConfig(
                mode="hint", hint=HintKind.BFW, hint_table=sched.table,
                hint_table_version=sched.version,
                metrics=sched.registry)).run().makespan)
            sched.maybe_resynthesize(k)
        assert sched.swaps == [] and sched.version == 0
        assert len(set(mks)) == 1  # jitter-free: bitwise-identical steps

    def test_to_json_roundtrips_decisions(self):
        spec, costs = _split_workload()
        sched = AdaptiveScheduler(
            spec, costs, AdaptiveConfig(hint=HintKind.BFW))
        sched.maybe_resynthesize(0)
        blob = sched.to_json()
        assert blob["version"] == 0
        assert blob["config"]["hint"] == HintKind.BFW.value
        assert blob["decisions"][0]["reason"].startswith("cold")


class TestDriftChaos:
    def test_step_profile_scale(self):
        ch = drift_chaos("step", {1: 3.0}, period=5)
        for k, want in ((0, 1.0), (4, 1.0), (5, 3.0), (9, 3.0)):
            assert dataclasses.replace(ch, step=k).drift_scale(1) == want
        assert dataclasses.replace(ch, step=7).drift_scale(0) == 1.0

    def test_ramp_profile_scale(self):
        ch = drift_chaos("ramp", ((2, 2.0),), period=4)
        got = [dataclasses.replace(ch, step=k).drift_scale(2)
               for k in range(6)]
        assert got == [1.0, 1.25, 1.5, 1.75, 2.0, 2.0]

    def test_dict_and_pair_targets_equivalent(self):
        a = drift_chaos("ramp", {0: 1.5, 2: 2.0}, period=3)
        b = drift_chaos("ramp", ((0, 1.5), (2, 2.0)), period=3)
        assert a.drift == b.drift

    def test_drift_alone_makes_chaos_active(self):
        assert not ChaosConfig().active()
        assert drift_chaos("step", {0: 2.0}).active()
        # a profile with no targets is still inert
        assert not drift_chaos("step", ()).active()

    def test_bad_profile_rejected(self):
        with pytest.raises(ValueError, match="drift_profile"):
            ChaosConfig(drift_profile="sawtooth")

    def test_parse_chaos_drift_syntax(self):
        ch = parse_chaos(
            "drift_profile=ramp,drift=1:2.5+3:4.0,drift_period=6,step=2")
        assert ch.drift_profile == "ramp"
        assert ch.drift == ((1, 2.5), (3, 4.0))
        assert ch.drift_period == 6 and ch.step == 2
        assert ch.drift_scale(1) == pytest.approx(1.0 + 1.5 * (2 / 6))

    def test_compute_scale_composes_with_straggler(self):
        from repro.runtime.rrfp.chaos import ChaosEngine

        ch = drift_chaos("step", {1: 2.0}, period=0,
                         level=ChaosConfig(straggler=((1, 3.0),)))
        assert ChaosEngine(ch).compute_scale(1) == pytest.approx(6.0)
        assert ChaosEngine(ch).compute_scale(0) == 1.0


class TestPricing:
    def test_price_orders_matches_actor_realization(self):
        # pricing a table with the DES engine must predict exactly what
        # the (jitter-free) actor runtime realizes for that table
        spec, costs = _split_workload(S=4, M=8)
        table = synthesize(spec, costs, hint=HintKind.BFW).stage_orders
        priced = price_orders(spec, table, costs)
        realized = ActorDriver(spec, costs, ActorConfig(
            mode="hint", hint=HintKind.BFW,
            hint_table=table)).run().makespan
        assert priced == pytest.approx(realized)

    def test_price_orders_ranks_tables_under_drift(self):
        # after a 2x drift on stage 4, the table synthesized against the
        # drifted costs must price no worse than the stale one
        spec = PipelineSpec(6, 18, split_backward=True)
        base = np.asarray((1.0, 1.2, 0.9, 1.3, 0.8, 1.1))
        costs = CostModel(
            f_cost=base, b_cost=base, w_cost=base, comm_base=0.4,
            compute_jitter=JitterModel(), comm_jitter=JitterModel())
        scale = np.where(np.arange(6) == 4, 2.0, 1.0)
        drifted = dataclasses.replace(
            costs, f_cost=base * scale, b_cost=base * scale,
            w_cost=base * scale)
        old = synthesize(spec, costs, hint=HintKind.BFW).stage_orders
        new = synthesize(spec, drifted, hint=HintKind.BFW).stage_orders
        p_old = price_orders(spec, old, drifted)
        p_new = price_orders(spec, new, drifted)
        assert p_new < p_old

    def test_synthesize_split_backward_uses_zb_baseline(self):
        # 1F1B is undefined for BFW specs; synthesis must not raise and
        # its baseline must be the ZB fixed order's makespan
        spec, costs = _split_workload(S=3, M=6)
        syn = synthesize(spec, costs, hint=HintKind.BFW)
        zb = ActorDriver(spec, costs, ActorConfig(
            mode="precommitted", fixed_order="zb")).run()
        assert syn.baseline_makespan == pytest.approx(zb.makespan)
        assert syn.predicted_speedup > 0
