"""Pipeline task graph: the dependency-constrained execution process of §3.1.

Tasks are forward (F), backward (B) and — under BFW decomposition — weight-update
(W) units at (stage, microbatch, chunk) granularity.  Edges are the paper's
inter-stage dependencies (F needs upstream activation, B needs downstream
gradient) and intra-stage dependencies (B needs the local F; W needs the local
B).  Interleaved (multi-chunk) pipelines wrap forward from the last stage back
to stage 0 at chunk boundaries.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterator


class Kind(enum.IntEnum):
    F = 0
    B = 1
    W = 2


@dataclasses.dataclass(frozen=True, order=True)
class Task:
    """One schedulable unit of pipeline work."""

    kind: Kind
    stage: int
    mb: int
    chunk: int = 0

    def __repr__(self) -> str:  # compact traces: F[s2,m5,c0]
        return f"{self.kind.name}[s{self.stage},m{self.mb},c{self.chunk}]"


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    """Static description of one training iteration's task graph."""

    num_stages: int
    num_microbatches: int
    num_chunks: int = 1
    split_backward: bool = False  # BFW: B computes dX only, W updates weights

    def __post_init__(self) -> None:
        if self.num_stages < 1 or self.num_microbatches < 1 or self.num_chunks < 1:
            raise ValueError(f"invalid spec {self}")

    # ---- enumeration -------------------------------------------------------
    def tasks(self) -> Iterator[Task]:
        for s in range(self.num_stages):
            for j in range(self.num_microbatches):
                for c in range(self.num_chunks):
                    yield Task(Kind.F, s, j, c)
                    yield Task(Kind.B, s, j, c)
                    if self.split_backward:
                        yield Task(Kind.W, s, j, c)

    def num_tasks_per_stage(self) -> int:
        per = 2 + (1 if self.split_backward else 0)
        return per * self.num_microbatches * self.num_chunks

    # ---- dependencies ------------------------------------------------------
    def message_predecessor(self, t: Task) -> Task | None:
        """The remote task whose *message* makes ``t`` ready (None = local/none).

        Forward activations flow s-1 -> s (wrapping S-1 -> 0 across chunks);
        backward gradients flow s+1 -> s (wrapping 0 -> S-1 across chunks).
        """
        s_last = self.num_stages - 1
        if t.kind == Kind.F:
            if t.stage > 0:
                return Task(Kind.F, t.stage - 1, t.mb, t.chunk)
            if t.chunk > 0:  # interleaved wrap
                return Task(Kind.F, s_last, t.mb, t.chunk - 1)
            return None  # stage 0, chunk 0: data is locally available
        if t.kind == Kind.B:
            if t.stage < s_last:
                return Task(Kind.B, t.stage + 1, t.mb, t.chunk)
            if t.chunk < self.num_chunks - 1:  # interleaved wrap
                return Task(Kind.B, 0, t.mb, t.chunk + 1)
            return None  # last stage, last chunk: loss gradient is local
        # W depends only on the local B.
        return None

    def message_successor(self, t: Task) -> Task | None:
        """The remote task whose readiness ``t``'s completion message feeds.

        Inverse of :meth:`message_predecessor`; shared by the DES engine and
        the host actor runtime so both route messages identically.
        """
        s_last = self.num_stages - 1
        if t.kind == Kind.F:
            if t.stage < s_last:
                return Task(Kind.F, t.stage + 1, t.mb, t.chunk)
            if t.chunk < self.num_chunks - 1:  # interleaved wrap
                return Task(Kind.F, 0, t.mb, t.chunk + 1)
            return None  # last stage: loss grad is local (B enabled locally)
        if t.kind == Kind.B:
            if t.stage > 0:
                return Task(Kind.B, t.stage - 1, t.mb, t.chunk)
            if t.chunk > 0:  # interleaved wrap
                return Task(Kind.B, s_last, t.mb, t.chunk - 1)
            return None
        # W is stage-local: its weight gradient feeds no other stage, so it
        # never emits a message and never passes a TP admission gate.
        return None

    def local_predecessor(self, t: Task) -> Task | None:
        """Same-stage dependency that must have *executed* before ``t``."""
        if t.kind == Kind.B:
            return Task(Kind.F, t.stage, t.mb, t.chunk)
        if t.kind == Kind.W:
            return Task(Kind.B, t.stage, t.mb, t.chunk)
        return None

    def predecessors(self, t: Task) -> list[Task]:
        out = []
        m = self.message_predecessor(t)
        if m is not None:
            out.append(m)
        l = self.local_predecessor(t)
        if l is not None:
            out.append(l)
        return out

    def total_tasks(self) -> int:
        return self.num_stages * self.num_tasks_per_stage()
