"""AdamW in pure JAX with ZeRO-1 sharded state (DESIGN §3).

Data-replicated parameters (bf16) keep fp32 master/m/v only on their
per-leaf reduce-scatter shard: the executor emits per-leaf grad shards, the
optimizer updates each shard and all-gathers the refreshed bf16 leaf.
Data-sharded leaves (EP/TP experts) update locally with their own m/v
(configurable dtype — bf16 keeps grok's 314B state in budget).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.pipeline.sharding import ParamPartition


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    expert_state_dtype: Any = jnp.float32


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(np.pi * prog))
    return cfg.lr * warm * cos


def _adamw_update(cfg: AdamWConfig, p, g, m, v, step, lr, scale=1.0):
    g = g.astype(jnp.float32) * scale
    m = cfg.beta1 * m + (1 - cfg.beta1) * g
    v = cfg.beta2 * v + (1 - cfg.beta2) * g * g
    mh = m / (1 - cfg.beta1 ** (step + 1))
    vh = v / (1 - cfg.beta2 ** (step + 1))
    upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
    return p - lr * upd, m, v


# ---------------------------------------------------------------------------
def make_host_update(opt_cfg: AdamWConfig):
    """Jitted single-pytree AdamW step for the host actor runtimes.

    ``apply_update(params, grads, m, v, step) -> (params, m, v, lr)`` —
    unsharded, any params/grads pytree (heterogeneous per-stage trees
    included).  Master arithmetic in float32; params cast back to their
    own dtype.
    """

    @jax.jit
    def apply_update(params, grads, m, v, step):
        lr = lr_at(opt_cfg, step)

        def upd(p, g, m_, v_):
            p32, m2, v2 = _adamw_update(
                opt_cfg, p.astype(jnp.float32), g.astype(jnp.float32),
                m_, v_, step, lr)
            return p32.astype(p.dtype), m2, v2

        out = jax.tree.map(upd, params, grads, m, v)
        tup = lambda o: isinstance(o, tuple)  # noqa: E731
        return (jax.tree.map(lambda o: o[0], out, is_leaf=tup),
                jax.tree.map(lambda o: o[1], out, is_leaf=tup),
                jax.tree.map(lambda o: o[2], out, is_leaf=tup), lr)

    return apply_update


# ---------------------------------------------------------------------------
def make_optimizer(model, mesh, partition: ParamPartition, opt_cfg: AdamWConfig,
                   dp_axes: tuple = ("data",)):
    """Returns (init_fn, update_fn) for the per-leaf ZeRO-1 optimizer."""
    dp_total = int(np.prod([mesh.shape[a] for a in dp_axes]))
    S = model.num_stages
    flags = partition.stage_data_sharded

    stage_leaves = list(
        jax.tree_util.tree_leaves_with_path(partition.stage_specs))
    flag_leaves = [f for _, f in
                   jax.tree_util.tree_leaves_with_path(flags)]
    io_leaves = list(jax.tree_util.tree_leaves_with_path(partition.io_specs))
    shard_keys = [jax.tree_util.keystr(p) for (p, _), f in
                  zip(stage_leaves, flag_leaves) if not f]
    shard_keys += ["io:" + jax.tree_util.keystr(p) for p, _ in io_leaves]
    expert_keys = [jax.tree_util.keystr(p) for (p, _), f in
                   zip(stage_leaves, flag_leaves) if f]

    def _dp_index():
        idx = jax.lax.axis_index(dp_axes[0])
        for a in dp_axes[1:]:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    def _my_shard(leaf):
        v = leaf.astype(jnp.float32).reshape(-1)
        v = jnp.pad(v, (0, (-v.size) % dp_total))
        return v.reshape(dp_total, -1)[_dp_index()]

    def _leaf_items(sp, io):
        """(key, leaf) pairs in executor grad-shard order."""
        items = []
        for (path, leaf), flag in zip(
                jax.tree_util.tree_leaves_with_path(sp), flag_leaves):
            if not flag:
                items.append((jax.tree_util.keystr(path), leaf))
        for path, leaf in jax.tree_util.tree_leaves_with_path(io):
            items.append(("io:" + jax.tree_util.keystr(path), leaf))
        return items

    # ---------------- init --------------------------------------------
    def device_init(stage_params, io):
        sp = jax.tree.map(lambda x: x[0], stage_params)
        shards = {}
        for k, leaf in _leaf_items(sp, io):
            m0 = _my_shard(leaf)
            shards[k] = {
                "master": m0[None],
                "m": jnp.zeros_like(m0)[None],
                "v": jnp.zeros_like(m0)[None],
            }
        experts = {}
        for (path, leaf), flag in zip(
                jax.tree_util.tree_leaves_with_path(sp), flag_leaves):
            if flag:
                k = jax.tree_util.keystr(path)
                experts[k] = {
                    "m": jnp.zeros(leaf.shape, opt_cfg.expert_state_dtype)[None],
                    "v": jnp.zeros(leaf.shape, opt_cfg.expert_state_dtype)[None],
                }
        return {"shards": shards, "experts": experts}

    expert_specs = {
        jax.tree_util.keystr(path): spec
        for (path, spec), flag in zip(stage_leaves, flag_leaves) if flag
    }
    shard_spec = P("model", dp_axes)
    state_specs = {
        "shards": {k: {"master": shard_spec, "m": shard_spec, "v": shard_spec}
                   for k in shard_keys},
        "experts": {k: {"m": s, "v": s} for k, s in expert_specs.items()},
    }

    init_fn = shard_map(
        device_init, mesh=mesh,
        in_specs=(partition.stage_specs, partition.io_specs),
        out_specs=state_specs, check_vma=False)

    # ---------------- update ------------------------------------------
    def device_update(stage_params, io, opt_state, grad_shards, expert_grads,
                      step):
        sp = jax.tree.map(lambda x: x[0], stage_params)
        lr = lr_at(opt_cfg, step)

        # global grad norm: stage segments distinct across model rows; io
        # segments replicated across rows (weight 1/S).
        sq = jnp.zeros((), jnp.float32)
        for k in shard_keys:
            g = grad_shards[k][0].astype(jnp.float32)
            w = 1.0 / S if k.startswith("io:") else 1.0
            sq = sq + w * jnp.sum(g * g)
        for k in expert_keys:
            eg = expert_grads[k][0].astype(jnp.float32)
            sq = sq + jnp.sum(eg * eg)
        gnorm = jnp.sqrt(jax.lax.psum(sq, ("model",) + dp_axes))
        scale = jnp.minimum(1.0, opt_cfg.grad_clip / (gnorm + 1e-12))

        # per-leaf shard update + all-gather refreshed bf16 leaves
        new_shards = {}
        new_leaves = {}
        for k, leaf in _leaf_items(sp, io):
            st = opt_state["shards"][k]
            mast, mn, vn = _adamw_update(
                opt_cfg, st["master"][0], grad_shards[k][0], st["m"][0],
                st["v"][0], step, lr, scale)
            new_shards[k] = {"master": mast[None], "m": mn[None], "v": vn[None]}
            full = jax.lax.all_gather(
                mast.astype(leaf.dtype), dp_axes, tiled=True)
            new_leaves[k] = full[: leaf.size].reshape(leaf.shape)

        new_experts = {}
        expert_leaves = {}
        for (path, leaf), flag in zip(
                jax.tree_util.tree_leaves_with_path(sp), flag_leaves):
            if not flag:
                continue
            k = jax.tree_util.keystr(path)
            st = opt_state["experts"][k]
            pn, mn, vn = _adamw_update(
                opt_cfg, leaf.astype(jnp.float32), expert_grads[k][0],
                st["m"][0].astype(jnp.float32),
                st["v"][0].astype(jnp.float32), step, lr, scale)
            expert_leaves[k] = pn.astype(leaf.dtype)
            new_experts[k] = {
                "m": mn.astype(opt_cfg.expert_state_dtype)[None],
                "v": vn.astype(opt_cfg.expert_state_dtype)[None],
            }

        def rebuild_sp(path, leaf):
            k = jax.tree_util.keystr(path)
            if k in expert_leaves:
                return expert_leaves[k]
            return new_leaves[k]

        sp_new = jax.tree_util.tree_map_with_path(rebuild_sp, sp)
        io_new = jax.tree_util.tree_map_with_path(
            lambda p, l: new_leaves["io:" + jax.tree_util.keystr(p)], io)
        new_state = {"shards": new_shards, "experts": new_experts}
        stats = {"gnorm": gnorm, "lr": lr}
        return (jax.tree.map(lambda x: x[None], sp_new), io_new, new_state,
                stats)

    grad_specs = {k: shard_spec for k in shard_keys}
    update_fn = shard_map(
        device_update, mesh=mesh,
        in_specs=(partition.stage_specs, partition.io_specs, state_specs,
                  grad_specs, expert_specs, P()),
        out_specs=(partition.stage_specs, partition.io_specs, state_specs,
                   {"gnorm": P(), "lr": P()}),
        check_vma=False)
    return init_fn, update_fn
