"""Randomized chaos scenarios on the sim substrate (virtual clock).

Each seed derives a scenario (spec × consumption mode × chaos config) and
runs one iteration through the actor runtime with fault injection: per-edge
latency, message reorder and duplication, stage stragglers, transient
stalls.  The recorded event trace is then checked against every
schedule-independent invariant (see ``harness.check_all``), and the run is
replayed time-exactly — the replayed trace must be bit-for-bit the recorded
one, makespan included.

Fast seeds run on every PR; the full matrix rides the ``slow`` marker.
"""
import dataclasses

import pytest

from harness import (
    artifact_on_failure,
    check_all,
    make_scenario,
    sim_costs,
)

from repro.runtime.rrfp import ActorConfig, ActorDriver

SIM_SEEDS_FAST = list(range(0, 24))
SIM_SEEDS_SLOW = list(range(24, 96))


def _run_scenario(seed: int) -> None:
    sc = make_scenario(seed)
    costs = sim_costs(sc.spec, seed)
    driver = ActorDriver(sc.spec, costs, sc.config)
    with artifact_on_failure(lambda: driver.trace, f"sim_{sc.name()}"):
        result = driver.run()  # deadlock-freedom: completes or raises
        trace = driver.trace
        assert trace is not None and trace.events
        check_all(trace, sc.spec, sc.config)

        # time-exact replay: identical event sequence and makespan
        rdriver = ActorDriver(
            sc.spec, None, ActorConfig(record_trace=True, replay=trace))
        replayed = rdriver.run()
        assert replayed.makespan == result.makespan
        assert rdriver.trace.signature() == trace.signature()


@pytest.mark.parametrize("seed", SIM_SEEDS_FAST)
def test_sim_chaos_scenario(seed):
    _run_scenario(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", SIM_SEEDS_SLOW)
def test_sim_chaos_scenario_full_matrix(seed):
    _run_scenario(seed)


def test_chaos_actually_perturbs_the_schedule():
    """Sanity: chaos changes realized dispatch orders (it is not a no-op)."""
    sc = make_scenario(3)
    costs = sim_costs(sc.spec, 3)
    chaotic = ActorDriver(sc.spec, costs, sc.config)
    chaotic.run()
    calm = ActorDriver(sc.spec, costs,
                       dataclasses.replace(sc.config, chaos=None))
    calm.run()
    assert (chaotic.trace.dispatch_orders(sc.spec.num_stages)
            != calm.trace.dispatch_orders(sc.spec.num_stages))


def test_same_chaos_hits_both_consumption_modes():
    """CRN keying: a scenario's chaos draws do not depend on the mode, so
    hint vs precommitted comparisons see the same injected faults."""
    from repro.core import PipelineSpec
    from repro.runtime.rrfp import ChaosConfig, ChaosEngine, Envelope
    from repro.core.taskgraph import Kind, Task

    chaos = ChaosEngine(ChaosConfig(
        seed=5, latency_base=1e-3, reorder_prob=0.5, reorder_window=1e-2,
        duplicate_prob=0.3))
    env = Envelope(task=Task(Kind.F, 1, 2), src_stage=0, dst_stage=1)
    # identical draws on repeated queries (stateless, keyed)
    assert chaos.comm_delay(env) == chaos.comm_delay(env)
    assert chaos.copies(env) == chaos.copies(env)
