"""Runtime observability: metrics, bubble attribution, Perfetto export,
online cost tables.

Layered strictly *on top of* the runtime (``repro.runtime.rrfp`` never
imports this package except lazily from ``Trace.to_perfetto``):

  metrics     -- per-stage single-writer shards: counters, gauges,
                 log-bucketed histograms; aggregated at sync points
  cost_table  -- per-(stage, op) duration EWMAs -> CostModel snapshots
                 (the online input for ROADMAP item 3 hint re-synthesis)
  bubbles     -- idle-time decomposition over recorded traces: warmup,
                 dependency-wait, starvation, TP-gate, backpressure, drain
  export      -- Chrome trace-event / Perfetto JSON rendering of traces

See ``docs/observability.md`` for the metric catalogue and semantics.
"""
from repro.obs.bubbles import (
    CATEGORIES,
    BubbleReport,
    StageBubbles,
    compare,
    decompose,
    spec_from_meta,
)
from repro.obs.cost_table import Ewma, OnlineCostTable
from repro.obs.export import export_perfetto, to_perfetto, validate_chrome_trace
from repro.obs.metrics import (
    DEPTH_EDGES,
    DURATION_EDGES,
    Histogram,
    MetricsRegistry,
    StageShard,
    log_edges,
)

__all__ = [
    "BubbleReport",
    "CATEGORIES",
    "DEPTH_EDGES",
    "DURATION_EDGES",
    "Ewma",
    "Histogram",
    "MetricsRegistry",
    "OnlineCostTable",
    "StageBubbles",
    "StageShard",
    "compare",
    "decompose",
    "export_perfetto",
    "log_edges",
    "spec_from_meta",
    "to_perfetto",
    "validate_chrome_trace",
]
