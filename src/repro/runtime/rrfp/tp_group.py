"""Tensor-parallel group consistency barrier (§4.2, Appendix D).

A stage with tp_degree K is K ranks executing in lockstep; the group can only
agree to dispatch a task once *all* ranks hold its input message.  The
:class:`TPGroup` tracks per-rank arrivals and admits a task at the arrival of
its last rank.  Whenever the per-rank arrival spread is nonzero the group has
been *deferred* by rank divergence — the paper's App. D counter.

Each collective-relevant dispatch additionally pays a scalar all-gather
(``coordination_cost``), calibrated to Table 3 like the DES engine.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.taskgraph import Kind, Task

from repro.runtime.rrfp.messages import Envelope


@dataclasses.dataclass
class Admission:
    """Result of the last-rank arrival that completed a task's message set."""

    task: Task
    admit_time: float
    spread: float  # max - min per-rank arrival time

    @property
    def deferred(self) -> bool:
        return self.spread > 0.0


class TPGroup:
    """All-ranks readiness gate for one pipeline stage."""

    def __init__(self, stage: int, tp_degree: int = 1):
        self.stage = stage
        self.tp_degree = max(1, tp_degree)
        self._held: dict[Task, dict[int, float]] = {}
        self.deferrals = 0
        self.admitted = 0

    def offer(self, env: Envelope, now: float) -> Admission | None:
        """Record one rank's copy; return an Admission when the set completes.

        Duplicate deliveries for a rank are idempotent (first arrival wins,
        matching a receive-side buffer that holds the message).
        """
        if env.dst_stage != self.stage:
            raise ValueError(
                f"envelope for stage {env.dst_stage} offered to group "
                f"{self.stage}")
        if not 0 <= env.rank < self.tp_degree:
            raise ValueError(f"rank {env.rank} out of range for K={self.tp_degree}")
        holds = self._held.setdefault(env.task, {})
        holds.setdefault(env.rank, now)
        if len(holds) < self.tp_degree:
            return None
        del self._held[env.task]
        times = sorted(holds.values())
        spread = times[-1] - times[0]
        if spread > 0:
            self.deferrals += 1
        self.admitted += 1
        return Admission(task=env.task, admit_time=now, spread=spread)

    def pending(self) -> dict[Task, int]:
        """Tasks with an incomplete rank set -> number of ranks still missing."""
        return {
            t: self.tp_degree - len(h) for t, h in self._held.items()
        }

    def coordination_cost(self, task: Task, base: float) -> float:
        """Per-dispatch scalar all-gather overhead (F/B only, like the engine)."""
        if self.tp_degree <= 1 or task.kind == Kind.W:
            return 0.0
        return base * (1.0 + math.log2(self.tp_degree))
